//! manifest.json parsing: artifact metadata, model configs, settings.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Static metadata of one compiled (or synthetic) artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// artifact name (manifest key)
    pub name: String,
    /// HLO text file, relative to the artifacts dir (compiled backends)
    pub hlo: String,
    /// weight-tensor names in executable argument order (before runtime
    /// inputs)
    pub params: Vec<String>,
    /// runtime input shapes (after the weight params)
    pub runtime_inputs: Vec<(Vec<usize>, String)>,
    /// output tensor names
    pub outputs: Vec<String>,
    /// `"prefill"` or `"decode"`
    pub kind: String,
    /// `"dense"` | `"nm"` | `"sq"` | `"sq_nm"`
    pub variant: String,
    /// static batch
    pub batch: usize,
    /// static sequence length (prefill only)
    pub seq: usize,
    /// static cache length (decode only)
    pub cache: usize,
    /// the N:M ratio baked into an nm artifact
    pub nm: Option<(usize, usize)>,
}

/// One model of the manifest's inventory.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// model name (manifest key)
    pub name: String,
    /// weight-file path, relative to the artifacts dir
    pub weights: String,
    /// whether the model is mixture-of-experts
    pub is_moe: bool,
    /// geometry config (d_model, n_layers, ...)
    pub config: BTreeMap<String, usize>,
}

/// Parsed `manifest.json`: the artifact + model inventory a backend
/// serves.
#[derive(Debug)]
pub struct Manifest {
    /// the artifacts directory the manifest was loaded from
    pub dir: PathBuf,
    /// artifact name -> metadata
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// model name -> info
    pub models: BTreeMap<String, ModelInfo>,
    /// model name -> available sparsity settings
    pub settings: BTreeMap<String, Vec<String>>,
    /// the raw parsed JSON (for fields this struct doesn't model)
    pub raw: Json,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let raw = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in raw
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let st = a.req("static")?;
            let params = a
                .req("params")?
                .as_arr()
                .ok_or_else(|| anyhow!("params not an array"))?
                .iter()
                .map(|p| p.as_str().unwrap_or_default().to_string())
                .collect();
            let runtime_inputs = a
                .req("runtime_inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("runtime_inputs not an array"))?
                .iter()
                .map(|ri| {
                    let shape = ri
                        .req("shape")
                        .ok()
                        .and_then(|s| s.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|d| d.as_usize())
                                .collect::<Vec<_>>()
                        })
                        .unwrap_or_default();
                    let dtype = ri
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    (shape, dtype)
                })
                .collect();
            let nm = match (st.get("n"), st.get("m")) {
                (Some(n), Some(m)) => Some((
                    n.as_usize().unwrap_or(0),
                    m.as_usize().unwrap_or(0),
                )),
                _ => None,
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    hlo: a.req_str("hlo")?.to_string(),
                    params,
                    runtime_inputs,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|o| o.as_str().unwrap_or("").to_string())
                        .collect(),
                    kind: st.req_str("kind")?.to_string(),
                    variant: st.req_str("variant")?.to_string(),
                    batch: st.req_usize("batch").unwrap_or(0),
                    seq: st.req_usize("seq").unwrap_or(0),
                    cache: st.req_usize("cache").unwrap_or(0),
                    nm,
                },
            );
        }
        let mut models = BTreeMap::new();
        if let Some(ms) = raw.get("models").and_then(|m| m.as_obj()) {
            for (name, m) in ms {
                let config = m
                    .get("config")
                    .and_then(|c| c.as_obj())
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| {
                                v.as_usize().map(|u| (k.clone(), u))
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                models.insert(
                    name.clone(),
                    ModelInfo {
                        name: name.clone(),
                        weights: m.req_str("weights")?.to_string(),
                        is_moe: m
                            .get("is_moe")
                            .and_then(|b| b.as_bool())
                            .unwrap_or(false),
                        config,
                    },
                );
            }
        }
        let mut settings = BTreeMap::new();
        if let Some(ss) = raw.get("settings").and_then(|m| m.as_obj()) {
            for (name, s) in ss {
                let list = s
                    .get("settings")
                    .and_then(|l| l.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|v| v.as_str().map(String::from))
                            .collect()
                    })
                    .unwrap_or_default();
                settings.insert(name.clone(), list);
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models, settings, raw })
    }

    /// The named artifact's metadata, or an error naming it.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Artifact naming convention helper:
    /// `<model>.prefill<seq>.<variant>` / `<model>.decode.<variant>`.
    pub fn prefill_name(
        model: &str,
        seq: usize,
        variant: &str,
        nm: Option<(usize, usize)>,
    ) -> String {
        match nm {
            Some((n, m)) => format!("{model}.prefill{seq}.{variant}{n}_{m}"),
            None => format!("{model}.prefill{seq}.{variant}"),
        }
    }

    /// Decode-artifact naming convention helper.
    pub fn decode_name(model: &str, variant: &str) -> String {
        format!("{model}.decode.{variant}")
    }
}
