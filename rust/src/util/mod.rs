//! Hand-rolled substrates. The offline environment only vendors the `xla`
//! and `anyhow` crates, so JSON, CLI parsing, PRNG and table formatting are
//! implemented here (DESIGN.md §6).

pub mod cli;
pub mod log;
pub mod fmt;
pub mod json;
pub mod rng;
