//! Deterministic PRNG (xoshiro256**) — reproducible workloads, property
//! tests and samplers without the `rand` crate.

/// xoshiro256** PRNG state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator (same seed -> same stream).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's multiply-shift with rejection for exactness
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [0, n), as usize.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element (panics on empty input).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_range_and_mean() {
        let mut r = Rng::new(3);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
