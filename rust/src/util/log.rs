//! Minimal leveled logger (env-controlled, no `log` crate facade needed
//! on the hot path — macros compile to a branch on a relaxed atomic).
//!
//! Level via `AMBER_LOG` = error|warn|info|debug|trace (default: warn).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// unrecoverable problems
    Error = 0,
    /// suspicious but survivable (the default threshold)
    Warn = 1,
    /// high-level progress
    Info = 2,
    /// verbose diagnostics
    Debug = 3,
    /// per-event firehose
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static INIT: OnceLock<()> = OnceLock::new();

/// The active threshold (initialized from `AMBER_LOG` on first call).
pub fn level() -> Level {
    INIT.get_or_init(|| {
        let lvl = match std::env::var("AMBER_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Warn,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the threshold programmatically.
pub fn set_level(l: Level) {
    INIT.get_or_init(|| ());
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether messages at `l` currently pass the threshold.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit one message to stderr if `l` passes the threshold.
pub fn log(l: Level, module: &str, msg: &str) {
    if enabled(l) {
        eprintln!("[{:5}] {module}: {msg}", format!("{l:?}").to_lowercase());
    }
}

/// Log at [`util::log::Level::Info`](crate::util::log::Level).
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), &format!($($arg)*))
    };
}

/// Log at [`util::log::Level::Debug`](crate::util::log::Level).
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), &format!($($arg)*))
    };
}

/// Log at [`util::log::Level::Warn`](crate::util::log::Level).
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
    }
}
