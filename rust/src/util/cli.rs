//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line: positionals, `--key value` options, flags.
#[derive(Debug, Default)]
pub struct Args {
    /// positional arguments, in order
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options
    pub options: BTreeMap<String, String>,
    /// bare `--flag` switches
    pub flags: Vec<String>,
    /// option keys that take a value (everything else is a bare flag)
    valued: Vec<&'static str>,
}

impl Args {
    /// Parse `argv`; `valued` lists option keys that consume a value.
    pub fn parse(argv: &[String], valued: &[&'static str]) -> Result<Args> {
        let mut out = Args { valued: valued.to_vec(), ..Default::default() };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if out.valued.contains(&rest) {
                    i += 1;
                    let v = argv.get(i).ok_or_else(|| {
                        anyhow!("option --{rest} expects a value")
                    })?;
                    out.options.insert(rest.to_string(), v.clone());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env(valued: &[&'static str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, valued)
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Integer option with default; errors on unparseable input.
    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}={v} not an integer: {e}")),
        }
    }

    /// Float option with default; errors on unparseable input.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}={v} not a number: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_args() {
        let a = Args::parse(
            &sv(&["serve", "--port", "8080", "--verbose", "--x=1"]),
            &["port"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.opt("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("x", 0).unwrap(), 1);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--port"]), &["port"]).is_err());
    }
}
