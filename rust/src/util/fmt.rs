//! Paper-style table rendering for the repro harnesses.

/// An aligned ASCII table (paper-table shaped).
pub struct Table {
    /// column headers
    pub header: Vec<String>,
    /// data rows
    pub rows: Vec<Vec<String>>,
    /// table caption
    pub title: String,
}

impl Table {
    /// An empty table with a caption and headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one data row.
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render to an aligned multi-line string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(c.len());
                } else {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// "0.6537" style cell for accuracies, "-2.3%" for drops.
pub fn acc(v: f64) -> String {
    format!("{:.4}", v)
}

/// Signed percentage-point delta cell, e.g. `-2.3%`.
pub fn pct_drop(baseline: f64, v: f64) -> String {
    let d = (v - baseline) * 100.0;
    format!("{}{:.1}%", if d >= 0.0 { "+" } else { "" }, d)
}

/// Milliseconds cell from seconds, e.g. `1.25ms`.
pub fn ms(v: f64) -> String {
    format!("{:.2}ms", v * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("| xx | 1    |"));
    }

    #[test]
    fn pct() {
        assert_eq!(pct_drop(0.70, 0.65), "-5.0%");
        assert_eq!(pct_drop(0.70, 0.71), "+1.0%");
    }
}
