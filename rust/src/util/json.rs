//! Minimal JSON: full parser + writer (RFC 8259 subset sufficient for our
//! manifests: no \u surrogate pairs beyond BMP, numbers as f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number, as f64
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["models", "tiny-lm-a", "weights"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with decent error messages.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("'{key}' not a string"))
    }

    /// Required numeric field, as usize.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    /// Serialize back to compact JSON text.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Object literal helper: `obj(vec![("k", num(1.0))])`.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number literal helper.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String literal helper.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        if self.peek()? != b'"' {
            bail!("expected string at byte {}", self.i);
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        self.i += len - 1;
                        out.push_str(std::str::from_utf8(
                            &self.b[start..start + len],
                        )?);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.i += 1; // [
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.i += 1; // {
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek()? != b':' {
                bail!("expected ':' at byte {}", self.i);
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true},
                      "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["b", "c"]).unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 1e3, 2.5E-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[2].as_f64().unwrap(), 1000.0);
        assert!((a[3].as_f64().unwrap() - 0.025).abs() < 1e-12);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }
}
