//! Exact N:M masks (rust mirror of `kernels/ref.py::nm_mask`).
//!
//! Scores are |x| * scale; within every group of `m` consecutive channels
//! the `n` highest-scoring survive; ties break toward the lower channel
//! index (stable ordering), keeping the pattern exactly N:M — the
//! structural requirement of the hardware SpMM format.

/// Keep-mask for one row. `x` length divisible by `m`; `scale` same length
/// (pass `&[]` for naive magnitude scoring).
pub fn nm_mask_scored(x: &[f32], scale: &[f32], n: usize, m: usize) -> Vec<bool> {
    assert!(x.len() % m == 0, "len {} % m {} != 0", x.len(), m);
    let mut mask = vec![false; x.len()];
    let mut idx: Vec<usize> = (0..m).collect();
    for g in 0..x.len() / m {
        let base = g * m;
        let score = |j: usize| {
            let s = if scale.is_empty() { 1.0 } else { scale[base + j] };
            x[base + j].abs() * s
        };
        idx.iter_mut().enumerate().for_each(|(i, v)| *v = i);
        // stable sort by descending score
        idx.sort_by(|&a, &b| {
            score(b)
                .partial_cmp(&score(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &j in idx.iter().take(n) {
            mask[base + j] = true;
        }
    }
    mask
}

/// Apply the mask: pruned copy of x.
pub fn nm_prune(x: &[f32], scale: &[f32], n: usize, m: usize) -> Vec<f32> {
    let mask = nm_mask_scored(x, scale, n, m);
    x.iter()
        .zip(mask)
        .map(|(&v, keep)| if keep { v } else { 0.0 })
        .collect()
}

/// Structural check: at most n nonzeros in every m-group.
pub fn validate_nm(x: &[f32], n: usize, m: usize) -> bool {
    if x.len() % m != 0 {
        return false;
    }
    x.chunks_exact(m)
        .all(|g| g.iter().filter(|v| **v != 0.0).count() <= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_counts() {
        let x = vec![1.0, -2.0, 3.0, 0.5, 4.0, 4.0, 4.0, 4.0];
        let p = nm_prune(&x, &[], 2, 4);
        assert!(validate_nm(&p, 2, 4));
        // group 1: all ties -> lower indices win
        assert_eq!(&p[4..], &[4.0, 4.0, 0.0, 0.0]);
        // group 0: keeps -2, 3
        assert_eq!(&p[..4], &[0.0, -2.0, 3.0, 0.0]);
    }

    #[test]
    fn scale_changes_selection() {
        let x = vec![1.0, 0.9, 0.1, 0.2];
        let p_naive = nm_prune(&x, &[], 1, 4);
        assert_eq!(p_naive, vec![1.0, 0.0, 0.0, 0.0]);
        let scale = vec![1.0, 1.0, 100.0, 1.0];
        let p_scored = nm_prune(&x, &scale, 1, 4);
        assert_eq!(p_scored, vec![0.0, 0.0, 0.1, 0.0]);
    }
}
