//! Analytic TPU/NPU performance model for the Layer-1 kernels
//! (DESIGN.md §5).
//!
//! Interpret-mode CPU timings are NOT an accelerator proxy, so the L1
//! perf deliverable is structural: given the BlockSpec geometry of the
//! kernels' `tpu` tile profile, estimate VMEM residency, HBM traffic
//! (weights amortized across the token-grid), and MXU/VPU cycles for the
//! dense vs fused-N:M projection step — in two hardware regimes:
//!
//! * **general-purpose** (`fused_selector = false`): the N:M top-k mask
//!   is computed on the VPU (m comparisons per element). This regime
//!   reproduces the paper's own observation that "current hardware …
//!   hinder[s] observed acceleration gains": at memory-bound tiles the
//!   selector overhead eats the compute win.
//! * **SpMM-unit** (`fused_selector = true`): an Ampere/Ascend-style
//!   sparse unit absorbs selection into the operand load path, so the
//!   step sees the full n/m compute scaling — the hardware the paper's
//!   "software-hardware co-optimization" pitch targets.
//!
//! Printed by `amber repro tpu-model`; quoted in EXPERIMENTS.md §Perf.

/// Accelerator parameters of the analytic model (defaults are a
/// TPUv5e-like part).
#[derive(Debug, Clone)]
pub struct TpuParams {
    /// on-chip vector memory, bytes
    pub vmem_bytes: u64,
    /// matrix-unit FLOPs per cycle
    pub mxu_flops_per_cycle: u64,
    /// core clock, Hz
    pub clock_hz: f64,
    /// HBM bandwidth, bytes/second
    pub hbm_bytes_per_sec: f64,
    /// vector-unit lanes (for the unfused selector cost)
    pub vpu_lanes: u64,
}

impl Default for TpuParams {
    fn default() -> Self {
        TpuParams {
            vmem_bytes: 16 << 20,
            mxu_flops_per_cycle: 2 * 128 * 128 * 8,
            clock_hz: 1.75e9,
            hbm_bytes_per_sec: 2.7e12,
            vpu_lanes: 8 * 128,
        }
    }
}

/// One projection kernel instance. `tokens_total` is the full prefill
/// token count (batch x seq): the weight tile streams from HBM once per
/// out-tile column and is reused across `tokens_total / token_tile` grid
/// steps, so its HBM cost is amortized.
#[derive(Debug, Clone)]
pub struct KernelGeometry {
    /// token rows per grid step
    pub token_tile: usize,
    /// total prefill tokens (batch x seq)
    pub tokens_total: usize,
    /// contraction width
    pub d_in: usize,
    /// output columns per grid step
    pub out_tile: usize,
    /// bytes per element
    pub dtype_bytes: usize,
}

/// Analytic cost estimate of one kernel grid step.
#[derive(Debug, Clone)]
pub struct KernelEstimate {
    /// resident tile bytes
    pub vmem_bytes: u64,
    /// fraction of VMEM the tiles occupy
    pub vmem_frac: f64,
    /// matrix-unit cycles
    pub mxu_cycles: f64,
    /// selector (top-k rank) cycles, 0 when fused
    pub selector_cycles: f64,
    /// HBM transfer cycles
    pub hbm_cycles: f64,
    /// the binding resource: "mxu" | "hbm" | "selector"
    pub bound: &'static str,
    /// achieved / peak matrix-unit utilization
    pub mxu_utilization: f64,
    /// estimated wall seconds per grid step
    pub est_secs_per_step: f64,
}

impl KernelGeometry {
    fn vmem_resident_bytes(&self) -> u64 {
        let x = self.token_tile * self.d_in;
        let w = self.d_in * self.out_tile;
        let o = self.token_tile * self.out_tile;
        ((x + w + o) * self.dtype_bytes) as u64
    }

    fn hbm_bytes_per_step(&self) -> f64 {
        let x = (self.token_tile * self.d_in) as f64;
        let o = (self.token_tile * self.out_tile) as f64;
        let reuse = (self.tokens_total / self.token_tile).max(1) as f64;
        let w = (self.d_in * self.out_tile) as f64 / reuse;
        (x + o + w) * self.dtype_bytes as f64
    }

    /// Estimate the dense kernel.
    pub fn estimate_dense(&self, p: &TpuParams) -> KernelEstimate {
        self.estimate(p, 1.0, 0.0)
    }

    /// Estimate the N:M kernel, with or without a fused selector unit.
    pub fn estimate_nm(&self, p: &TpuParams, n: usize, m: usize,
                       fused_selector: bool) -> KernelEstimate {
        let selector_cycles = if fused_selector {
            0.0
        } else {
            // VPU rank: m comparisons per element over the activation tile
            (self.token_tile * self.d_in * m) as f64 / p.vpu_lanes as f64
        };
        self.estimate(p, n as f64 / m as f64, selector_cycles)
    }

    fn estimate(&self, p: &TpuParams, compute_frac: f64,
                selector_cycles: f64) -> KernelEstimate {
        let flops = 2.0
            * self.token_tile as f64
            * self.d_in as f64
            * self.out_tile as f64
            * compute_frac;
        let mxu_cycles = flops / p.mxu_flops_per_cycle as f64;
        let hbm_cycles =
            self.hbm_bytes_per_step() / p.hbm_bytes_per_sec * p.clock_hz;
        let compute = mxu_cycles + selector_cycles;
        let total = compute.max(hbm_cycles);
        KernelEstimate {
            vmem_bytes: self.vmem_resident_bytes(),
            vmem_frac: self.vmem_resident_bytes() as f64
                / p.vmem_bytes as f64,
            mxu_cycles,
            selector_cycles,
            hbm_cycles,
            bound: if hbm_cycles > compute { "memory" } else { "compute" },
            mxu_utilization: mxu_cycles / total,
            est_secs_per_step: total / p.clock_hz,
        }
    }
}

/// Artifact kernels' TPU-profile geometry: 128-token tiles, 512-column
/// out tiles (width needed to stay compute-bound — at 128 columns the
/// x-tile streaming alone is the bottleneck and sparsity buys nothing),
/// bf16 operands, prefill of `tokens_total` tokens.
pub fn artifact_geometry(d_in: usize, d_out: usize, tokens_total: usize)
                         -> KernelGeometry {
    // widest out-tile (compute-bound) that keeps the block under half of
    // VMEM (double-buffering headroom)
    let budget = (TpuParams::default().vmem_bytes / 2) as usize;
    let mut out_tile = d_out.min(512);
    while out_tile > 128 {
        let g = KernelGeometry {
            token_tile: 128,
            tokens_total,
            d_in,
            out_tile,
            dtype_bytes: 2,
        };
        if (g.vmem_resident_bytes() as usize) <= budget {
            break;
        }
        out_tile /= 2;
    }
    KernelGeometry { token_tile: 128, tokens_total, d_in, out_tile,
                     dtype_bytes: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: usize = 4096; // prefill batch x seq

    #[test]
    fn vmem_fits() {
        let g = artifact_geometry(4096, 14336, T);
        let e = g.estimate_dense(&TpuParams::default());
        assert!(e.vmem_frac < 0.5, "tile must be VMEM-resident: {e:?}");
    }

    #[test]
    fn spmm_unit_delivers_compute_scaling() {
        let p = TpuParams::default();
        let g = artifact_geometry(4096, 4096, T);
        let d = g.estimate_dense(&p);
        let s = g.estimate_nm(&p, 2, 4, true);
        assert!(s.mxu_cycles < d.mxu_cycles * 0.51);
        assert!(
            s.est_secs_per_step < d.est_secs_per_step,
            "fused nm {} !< dense {}",
            s.est_secs_per_step,
            d.est_secs_per_step
        );
    }

    #[test]
    fn general_purpose_selector_eats_the_win() {
        // the paper's observed no-speedup regime: without SpMM-unit
        // support the VPU selector overhead cancels the compute saving
        let p = TpuParams::default();
        let g = artifact_geometry(4096, 4096, T);
        let d = g.estimate_dense(&p);
        let s = g.estimate_nm(&p, 2, 4, false);
        assert!(s.est_secs_per_step >= d.est_secs_per_step * 0.8);
    }

    #[test]
    fn weight_amortization_matters() {
        let p = TpuParams::default();
        let big = artifact_geometry(4096, 4096, T).estimate_dense(&p);
        let small = KernelGeometry {
            tokens_total: 128,
            ..artifact_geometry(4096, 4096, T)
        }
        .estimate_dense(&p);
        // decode-like (no reuse) must be far more memory-bound
        assert_eq!(small.bound, "memory");
        assert!(small.hbm_cycles > big.hbm_cycles * 2.0);
    }
}
