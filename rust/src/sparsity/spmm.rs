//! Native N:M compressed SpMM — the CPU stand-in for the sparse matmul
//! unit the paper targets (Ascend / Ampere sparse tensor cores).
//!
//! An N:M-pruned activation row compresses to `din * n / m` (value, index)
//! pairs; the matmul then touches only the surviving channels' weight
//! rows, doing exactly n/m of the dense multiply-adds — the same compute
//! scaling the hardware SpMM delivers. `cargo bench --bench spmm` measures
//! dense vs compressed wall-clock across ratios and sizes (PERF row of the
//! experiment index).
//!
//! Bench fairness: [`dense_matmul`] is a *true* dense baseline — it does
//! the full `t*din*dout` multiply-adds with no zero-skipping, so a pruned
//! input cannot silently turn the baseline sparse. The zero-skipping
//! variant lives on as [`dense_matmul_skip_zeros`] (it is what a
//! scalar-sparse CPU kernel would do), and [`dense_matmul_counted`] pins
//! the FLOP behavior of both in tests.
//!
//! Since the register-tiled kernel core landed, every matmul here
//! dispatches to [`crate::kernels`] (`dout`-tiled accumulators kept in
//! registers) and is **bitwise identical** to the retained naive loops
//! in [`crate::kernels::reference`] — `tests/kernel_parity.rs` pins the
//! contract. The `*_with_tile` variants expose the tile-width knob; the
//! plain names use [`crate::kernels::DEFAULT_DOUT_TILE`].

use std::sync::Arc;

use super::mask::nm_mask_scored;
use crate::exec::ThreadPool;
use crate::kernels::pack::PackedPanels;
use crate::kernels::simd::Dispatch;
use crate::kernels::{self, DEFAULT_DOUT_TILE};

/// Compressed N:M activation matrix [t, din*n/m] with per-element group
/// channel indices.
pub struct NmCompressed {
    /// token rows
    pub t: usize,
    /// dense contraction width
    pub din: usize,
    /// survivors per group
    pub n: usize,
    /// group size
    pub m: usize,
    /// surviving values, row-major [t, din/m, n]
    pub values: Vec<f32>,
    /// absolute channel index of each surviving value
    pub index: Vec<u32>,
}

/// FLOP accounting of one SpMM call.
#[derive(Debug, Default, Clone, Copy)]
pub struct SpmmStats {
    /// multiply-add FLOPs the dense matmul would cost
    pub dense_flops: u64,
    /// multiply-add FLOPs the compressed matmul executes
    pub sparse_flops: u64,
}

impl NmCompressed {
    /// Compress a dense [t, din] matrix with scored N:M pruning.
    ///
    /// # Panics
    /// With a clear message when the ratio is malformed (`n == 0`,
    /// `n > m`), when `din` is not a multiple of the group size `m`, or
    /// when `x` is not `t * din` long — the structural preconditions of
    /// the hardware SpMM format.
    pub fn compress(
        x: &[f32],
        t: usize,
        din: usize,
        scale: &[f32],
        n: usize,
        m: usize,
    ) -> NmCompressed {
        assert!(
            n >= 1 && n <= m,
            "compress: malformed N:M ratio {n}:{m} (need 1 <= n <= m)"
        );
        assert!(
            din % m == 0,
            "compress: din {din} is not divisible by the N:M group \
             size m = {m}"
        );
        assert_eq!(
            x.len(),
            t * din,
            "compress: x has {} elements, expected t*din = {}x{}",
            x.len(),
            t,
            din
        );
        let groups = din / m;
        let mut values = Vec::with_capacity(t * groups * n);
        let mut index = Vec::with_capacity(t * groups * n);
        for r in 0..t {
            let row = &x[r * din..(r + 1) * din];
            let mask = nm_mask_scored(row, scale, n, m);
            for g in 0..groups {
                let mut cnt = 0;
                for j in 0..m {
                    let c = g * m + j;
                    if mask[c] {
                        values.push(row[c]);
                        index.push(c as u32);
                        cnt += 1;
                    }
                }
                debug_assert_eq!(cnt, n);
            }
        }
        NmCompressed { t, din, n, m, values, index }
    }

    /// Decompress back to dense (tests / verification).
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.t * self.din];
        let per_row = self.din / self.m * self.n;
        for r in 0..self.t {
            for k in 0..per_row {
                let v = self.values[r * per_row + k];
                let c = self.index[r * per_row + k] as usize;
                out[r * self.din + c] = v;
            }
        }
        out
    }

    /// Compressed matmul: self [t, din] (sparse) x w [din, dout] -> dense
    /// [t, dout]. Only surviving channels' weight rows are touched.
    /// Runs the register-tiled kernel at the default tile width.
    pub fn matmul(&self, w: &[f32], dout: usize) -> Vec<f32> {
        self.matmul_with_tile(w, dout, DEFAULT_DOUT_TILE)
    }

    /// [`NmCompressed::matmul`] with an explicit `dout`-tile width —
    /// bitwise identical for every width (the knob is pure perf).
    pub fn matmul_with_tile(
        &self,
        w: &[f32],
        dout: usize,
        dout_tile: usize,
    ) -> Vec<f32> {
        assert_eq!(w.len(), self.din * dout);
        let per_row = self.din / self.m * self.n;
        let mut out = vec![0.0f32; self.t * dout];
        kernels::nm::spmm_nm_tiled(
            &self.values,
            &self.index,
            self.t,
            per_row,
            w,
            dout,
            dout_tile,
            &mut out,
        );
        out
    }

    /// [`NmCompressed::matmul`] against a panel-packed weight —
    /// bitwise identical to the row-major paths (the packing is a pure
    /// layout transform; see [`crate::kernels::pack`]).
    pub fn matmul_packed(&self, w: &PackedPanels<f32>) -> Vec<f32> {
        assert_eq!(w.din, self.din, "packed weight contraction width");
        let per_row = self.din / self.m * self.n;
        let mut out = vec![0.0f32; self.t * w.dout];
        kernels::nm::spmm_nm_tiled_packed(
            &self.values,
            &self.index,
            self.t,
            per_row,
            w,
            &mut out,
        );
        out
    }

    /// Dense vs executed FLOPs for a matmul against `dout` columns.
    pub fn stats(&self, dout: usize) -> SpmmStats {
        SpmmStats {
            dense_flops: 2 * (self.t * self.din * dout) as u64,
            sparse_flops: 2 * (self.t * self.din * dout) as u64
                * self.n as u64
                / self.m as u64,
        }
    }
}

/// One row-tile of an [`NmCompressedBatch`]: `rows` consecutive token
/// rows in the same compressed (value, channel-index) layout as
/// [`NmCompressed`]. Blocks are `Arc`-shared so the tiled SpMM can fan
/// them out over a [`ThreadPool`] without copying the sparse data.
pub struct NmBlock {
    /// first token row this block covers
    pub row0: usize,
    /// number of token rows in this block
    pub rows: usize,
    /// surviving values, row-major `[rows, din/m*n]`
    pub values: Vec<f32>,
    /// absolute channel index of each surviving value
    pub index: Vec<u32>,
}

impl NmBlock {
    /// Per-row-tile matmul — the same register-tiled kernel (and so the
    /// same per-element float-op order) as [`NmCompressed::matmul`], so
    /// outputs are bit-identical regardless of the row tiling.
    fn matmul(
        &self,
        w: &[f32],
        din: usize,
        n: usize,
        m: usize,
        dout: usize,
        dout_tile: usize,
    ) -> Vec<f32> {
        let per_row = din / m * n;
        let mut out = vec![0.0f32; self.rows * dout];
        kernels::nm::spmm_nm_tiled(
            &self.values,
            &self.index,
            self.rows,
            per_row,
            w,
            dout,
            dout_tile,
            &mut out,
        );
        out
    }

    /// Per-row-tile matmul against a panel-packed weight — same
    /// per-element float-op order, bit-identical to
    /// [`NmBlock::matmul`].
    fn matmul_packed(
        &self,
        w: &PackedPanels<f32>,
        din: usize,
        n: usize,
        m: usize,
    ) -> Vec<f32> {
        self.matmul_packed_dispatch(w, din, n, m, Dispatch::scalar())
    }

    /// [`NmBlock::matmul_packed`] through a resolved SIMD [`Dispatch`]
    /// vtable — bitwise identical at every level (the SIMD kernels
    /// preserve each element's scalar reduction chain).
    fn matmul_packed_dispatch(
        &self,
        w: &PackedPanels<f32>,
        din: usize,
        n: usize,
        m: usize,
        disp: Dispatch,
    ) -> Vec<f32> {
        let per_row = din / m * n;
        let mut out = vec![0.0f32; self.rows * w.dout];
        (disp.spmm)(
            &self.values,
            &self.index,
            self.rows,
            per_row,
            w,
            &mut out,
        );
        out
    }
}

/// Block-compressed N:M activation batch: a whole `[t, din]` activation
/// matrix compressed **once** into row-tiles of `block_rows` token rows
/// each (a blocked CSR analogue with implicit per-row offsets — exact N:M
/// makes every row's nnz the same). The tiled SpMM runs each tile
/// independently, serially or fanned out over the engine's
/// [`ThreadPool`]; because every row's compressed layout and axpy order
/// match [`NmCompressed`] exactly, the result is bit-identical to the
/// per-row path regardless of tiling or pool width.
pub struct NmCompressedBatch {
    /// token rows
    pub t: usize,
    /// dense contraction width
    pub din: usize,
    /// survivors per group
    pub n: usize,
    /// group size
    pub m: usize,
    /// row-tile height the batch was compressed with
    pub block_rows: usize,
    blocks: Vec<Arc<NmBlock>>,
}

/// Default row-tile height for the batched kernels: small enough to give
/// a pool useful parallel slack at serving batch sizes, large enough to
/// amortize per-tile dispatch.
pub const DEFAULT_BLOCK_ROWS: usize = 32;

impl NmCompressedBatch {
    /// Compress a dense `[t, din]` matrix with scored N:M pruning into
    /// row-blocks. Same preconditions (and panic messages) as
    /// [`NmCompressed::compress`]; `block_rows` is clamped to >= 1.
    pub fn compress(
        x: &[f32],
        t: usize,
        din: usize,
        scale: &[f32],
        n: usize,
        m: usize,
        block_rows: usize,
    ) -> NmCompressedBatch {
        assert!(
            n >= 1 && n <= m,
            "compress: malformed N:M ratio {n}:{m} (need 1 <= n <= m)"
        );
        assert!(
            din % m == 0,
            "compress: din {din} is not divisible by the N:M group \
             size m = {m}"
        );
        assert_eq!(
            x.len(),
            t * din,
            "compress: x has {} elements, expected t*din = {}x{}",
            x.len(),
            t,
            din
        );
        let block_rows = block_rows.max(1);
        let groups = din / m;
        let mut blocks = Vec::with_capacity(t.div_ceil(block_rows));
        let mut row0 = 0;
        while row0 < t {
            let rows = block_rows.min(t - row0);
            let mut values = Vec::with_capacity(rows * groups * n);
            let mut index = Vec::with_capacity(rows * groups * n);
            for r in row0..row0 + rows {
                let row = &x[r * din..(r + 1) * din];
                let mask = nm_mask_scored(row, scale, n, m);
                for g in 0..groups {
                    let mut cnt = 0;
                    for j in 0..m {
                        let c = g * m + j;
                        if mask[c] {
                            values.push(row[c]);
                            index.push(c as u32);
                            cnt += 1;
                        }
                    }
                    debug_assert_eq!(cnt, n);
                }
            }
            blocks.push(Arc::new(NmBlock { row0, rows, values, index }));
            row0 += rows;
        }
        NmCompressedBatch { t, din, n, m, block_rows, blocks }
    }

    /// Row-tiles the batch compressed into.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Decompress back to dense (validation / the int8 reference path).
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.t * self.din];
        let per_row = self.din / self.m * self.n;
        for b in &self.blocks {
            for r in 0..b.rows {
                for k in 0..per_row {
                    let v = b.values[r * per_row + k];
                    let c = b.index[r * per_row + k] as usize;
                    out[(b.row0 + r) * self.din + c] = v;
                }
            }
        }
        out
    }

    /// Serial tiled SpMM: every row-tile on the calling thread, outputs
    /// concatenated in row order. Runs the register-tiled kernel at the
    /// default `dout`-tile width.
    pub fn matmul(&self, w: &[f32], dout: usize) -> Vec<f32> {
        self.matmul_with_tile(w, dout, DEFAULT_DOUT_TILE)
    }

    /// [`NmCompressedBatch::matmul`] with an explicit `dout`-tile width
    /// — bitwise identical for every width.
    pub fn matmul_with_tile(
        &self,
        w: &[f32],
        dout: usize,
        dout_tile: usize,
    ) -> Vec<f32> {
        assert_eq!(w.len(), self.din * dout);
        let mut out = vec![0.0f32; self.t * dout];
        for b in &self.blocks {
            let tile =
                b.matmul(w, self.din, self.n, self.m, dout, dout_tile);
            out[b.row0 * dout..(b.row0 + b.rows) * dout]
                .copy_from_slice(&tile);
        }
        out
    }

    /// Parallel tiled SpMM: row-tiles fanned out over `pool`
    /// ([`ThreadPool::map`] keeps tile order, so assembly is a straight
    /// concatenation). Falls back to the serial path when the pool has a
    /// single worker or there is only one tile — the result is
    /// bit-identical either way.
    pub fn matmul_parallel(
        &self,
        w: &Arc<Vec<f32>>,
        dout: usize,
        pool: &ThreadPool,
    ) -> Vec<f32> {
        self.matmul_parallel_with_tile(w, dout, pool, DEFAULT_DOUT_TILE)
    }

    /// [`NmCompressedBatch::matmul_parallel`] with an explicit
    /// `dout`-tile width — bitwise identical for every width and pool.
    pub fn matmul_parallel_with_tile(
        &self,
        w: &Arc<Vec<f32>>,
        dout: usize,
        pool: &ThreadPool,
        dout_tile: usize,
    ) -> Vec<f32> {
        assert_eq!(w.len(), self.din * dout);
        if pool.size() <= 1 || self.blocks.len() <= 1 {
            return self.matmul_with_tile(w, dout, dout_tile);
        }
        let (din, n, m) = (self.din, self.n, self.m);
        let w = Arc::clone(w);
        let tiles = pool.map(self.blocks.clone(), move |b| {
            b.matmul(&w, din, n, m, dout, dout_tile)
        });
        let mut out = vec![0.0f32; self.t * dout];
        for (b, tile) in self.blocks.iter().zip(tiles) {
            out[b.row0 * dout..(b.row0 + b.rows) * dout]
                .copy_from_slice(&tile);
        }
        out
    }

    /// Serial tiled SpMM against a panel-packed weight — bitwise
    /// identical to [`NmCompressedBatch::matmul`] for every panel
    /// width; the weight panels stream unit-stride.
    pub fn matmul_packed(&self, w: &PackedPanels<f32>) -> Vec<f32> {
        self.matmul_packed_dispatch(w, Dispatch::scalar())
    }

    /// [`NmCompressedBatch::matmul_packed`] through a resolved SIMD
    /// [`Dispatch`] vtable — bitwise identical at every level.
    pub fn matmul_packed_dispatch(
        &self,
        w: &PackedPanels<f32>,
        disp: Dispatch,
    ) -> Vec<f32> {
        assert_eq!(w.din, self.din, "packed weight contraction width");
        let dout = w.dout;
        let mut out = vec![0.0f32; self.t * dout];
        for b in &self.blocks {
            let tile = b.matmul_packed_dispatch(
                w, self.din, self.n, self.m, disp,
            );
            out[b.row0 * dout..(b.row0 + b.rows) * dout]
                .copy_from_slice(&tile);
        }
        out
    }

    /// Parallel tiled SpMM against a panel-packed weight: row-tiles
    /// fanned out over `pool`, the packed weight `Arc`-shared with the
    /// workers (zero copies). Bit-identical to
    /// [`NmCompressedBatch::matmul_packed`] for every pool width.
    pub fn matmul_packed_parallel(
        &self,
        w: &Arc<PackedPanels<f32>>,
        pool: &ThreadPool,
    ) -> Vec<f32> {
        self.matmul_packed_parallel_dispatch(w, pool, Dispatch::scalar())
    }

    /// [`NmCompressedBatch::matmul_packed_parallel`] through a resolved
    /// SIMD [`Dispatch`] vtable (the `Copy` vtable rides into the pool
    /// workers) — bitwise identical at every level and pool width.
    pub fn matmul_packed_parallel_dispatch(
        &self,
        w: &Arc<PackedPanels<f32>>,
        pool: &ThreadPool,
        disp: Dispatch,
    ) -> Vec<f32> {
        assert_eq!(w.din, self.din, "packed weight contraction width");
        if pool.size() <= 1 || self.blocks.len() <= 1 {
            return self.matmul_packed_dispatch(w, disp);
        }
        let (din, n, m, dout) = (self.din, self.n, self.m, w.dout);
        let w = Arc::clone(w);
        let tiles = pool.map(self.blocks.clone(), move |b| {
            b.matmul_packed_dispatch(&w, din, n, m, disp)
        });
        let mut out = vec![0.0f32; self.t * dout];
        for (b, tile) in self.blocks.iter().zip(tiles) {
            out[b.row0 * dout..(b.row0 + b.rows) * dout]
                .copy_from_slice(&tile);
        }
        out
    }

    /// Dense vs executed FLOPs for a matmul against `dout` columns.
    pub fn stats(&self, dout: usize) -> SpmmStats {
        SpmmStats {
            dense_flops: 2 * (self.t * self.din * dout) as u64,
            sparse_flops: 2 * (self.t * self.din * dout) as u64
                * self.n as u64
                / self.m as u64,
        }
    }
}

/// Panel-packed dense matmul: [`dense_matmul`] with the weight in
/// tile-panel layout — bitwise identical for every panel width.
pub fn dense_matmul_packed(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
) -> Vec<f32> {
    dense_matmul_packed_dispatch(x, t, din, w, Dispatch::scalar())
}

/// [`dense_matmul_packed`] through a resolved SIMD [`Dispatch`] vtable
/// — bitwise identical at every level.
pub fn dense_matmul_packed_dispatch(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    disp: Dispatch,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * w.dout];
    (disp.dense)(x, t, din, w, &mut out);
    out
}

/// Row-tiled parallel variant of [`dense_matmul_packed`]: rows are
/// chunked into `block_rows`-high tiles fanned out over `pool`, with
/// both the activation and the packed weight `Arc`-shared with the
/// workers (zero copies either way). Bit-identical to the serial
/// packed kernel for every tiling and pool width.
pub fn dense_matmul_packed_parallel(
    x: &Arc<Vec<f32>>,
    t: usize,
    din: usize,
    w: &Arc<PackedPanels<f32>>,
    pool: &ThreadPool,
    block_rows: usize,
) -> Vec<f32> {
    dense_matmul_packed_parallel_dispatch(
        x,
        t,
        din,
        w,
        pool,
        block_rows,
        Dispatch::scalar(),
    )
}

/// [`dense_matmul_packed_parallel`] through a resolved SIMD
/// [`Dispatch`] vtable — bitwise identical at every level, tiling and
/// pool width.
#[allow(clippy::too_many_arguments)]
pub fn dense_matmul_packed_parallel_dispatch(
    x: &Arc<Vec<f32>>,
    t: usize,
    din: usize,
    w: &Arc<PackedPanels<f32>>,
    pool: &ThreadPool,
    block_rows: usize,
    disp: Dispatch,
) -> Vec<f32> {
    assert_eq!(x.len(), t * din);
    assert_eq!(w.din, din, "packed weight contraction width");
    let block_rows = block_rows.max(1);
    if pool.size() <= 1 || t <= block_rows {
        return dense_matmul_packed_dispatch(x, t, din, w, disp);
    }
    let mut tiles_spec: Vec<(usize, usize)> = Vec::new();
    let mut row0 = 0;
    while row0 < t {
        let rows = block_rows.min(t - row0);
        tiles_spec.push((row0, rows));
        row0 += rows;
    }
    let xs = Arc::clone(x);
    let w2 = Arc::clone(w);
    let tiles = pool.map(tiles_spec, move |(row0, rows)| {
        dense_matmul_packed_dispatch(
            &xs[row0 * din..(row0 + rows) * din],
            rows,
            din,
            &w2,
            disp,
        )
    });
    // map preserves tile order: assembly is a straight concatenation
    let mut out = Vec::with_capacity(t * w.dout);
    for tile in tiles {
        out.extend_from_slice(&tile);
    }
    out
}

/// Row-tiled parallel variant of [`dense_matmul`]: rows are chunked into
/// `block_rows`-high tiles and fanned out over `pool`. Each row runs the
/// same register-tiled kernel as [`dense_matmul`], so the output is
/// bit-identical to the serial kernel for every tiling and pool width.
///
/// **Zero-copy**: the activation arrives as an `Arc` threaded from the
/// pipeline (pool jobs are `'static`, so a borrowed slice cannot cross
/// into the workers) — nothing is copied per call; workers slice their
/// row range out of the shared buffer.
#[allow(clippy::too_many_arguments)]
pub fn dense_matmul_parallel(
    x: &Arc<Vec<f32>>,
    t: usize,
    din: usize,
    w: &Arc<Vec<f32>>,
    dout: usize,
    pool: &ThreadPool,
    block_rows: usize,
    dout_tile: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), t * din);
    assert_eq!(w.len(), din * dout);
    let block_rows = block_rows.max(1);
    if pool.size() <= 1 || t <= block_rows {
        return dense_matmul_with_tile(x, t, din, w, dout, dout_tile);
    }
    let mut tiles_spec: Vec<(usize, usize)> = Vec::new();
    let mut row0 = 0;
    while row0 < t {
        let rows = block_rows.min(t - row0);
        tiles_spec.push((row0, rows));
        row0 += rows;
    }
    let xs = Arc::clone(x);
    let w2 = Arc::clone(w);
    let tiles = pool.map(tiles_spec, move |(row0, rows)| {
        dense_matmul_with_tile(
            &xs[row0 * din..(row0 + rows) * din],
            rows,
            din,
            &w2,
            dout,
            dout_tile,
        )
    });
    // map preserves tile order: assembly is a straight concatenation
    let mut out = Vec::with_capacity(t * dout);
    for tile in tiles {
        out.extend_from_slice(&tile);
    }
    out
}

/// Dense matmul (row-major x [t, din] @ w [din, dout]) through the
/// register-tiled kernel at the default tile width. Performs the full
/// `t*din*dout` multiply-adds unconditionally — zeros in `x` are
/// multiplied like any other value, exactly as a dense MXU would — and
/// is bitwise identical to [`crate::kernels::reference::dense`].
pub fn dense_matmul(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
) -> Vec<f32> {
    dense_matmul_with_tile(x, t, din, w, dout, DEFAULT_DOUT_TILE)
}

/// [`dense_matmul`] with an explicit `dout`-tile width — bitwise
/// identical for every width.
pub fn dense_matmul_with_tile(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    dout_tile: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * dout];
    kernels::dense::dense_tiled(x, t, din, w, dout, dout_tile, &mut out);
    out
}

/// The scalar-sparse variant of [`dense_matmul`]: skips zero input
/// channels. On a pruned input this does only the surviving fraction of
/// the work — useful as a *third* bench series (what a branchy CPU kernel
/// achieves without the compressed format), but NOT a dense baseline.
pub fn dense_matmul_skip_zeros(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * dout];
    for r in 0..t {
        let orow = &mut out[r * dout..(r + 1) * dout];
        let xrow = &x[r * din..(r + 1) * din];
        for (c, &v) in xrow.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let wrow = &w[c * dout..(c + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += v * wv;
            }
        }
    }
    out
}

/// Instrumented matmul pinning FLOP behavior: returns the output plus the
/// number of multiply-add row operations actually executed (`din`-axis
/// channels x `dout` each). With `skip_zeros == false` this is always
/// `t * din`, regardless of how sparse `x` is — the regression contract
/// that keeps the dense bench baseline honest.
pub fn dense_matmul_counted(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    skip_zeros: bool,
) -> (Vec<f32>, u64) {
    let mut out = vec![0.0f32; t * dout];
    let mut rows_touched = 0u64;
    for r in 0..t {
        let orow = &mut out[r * dout..(r + 1) * dout];
        let xrow = &x[r * din..(r + 1) * din];
        for (c, &v) in xrow.iter().enumerate() {
            if skip_zeros && v == 0.0 {
                continue;
            }
            rows_touched += 1;
            let wrow = &w[c * dout..(c + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += v * wv;
            }
        }
    }
    (out, rows_touched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn compress_roundtrip_and_matmul() {
        let mut rng = Rng::new(1);
        let (t, din, dout) = (8, 32, 16);
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        for &(n, m) in &[(2usize, 4usize), (4, 8), (8, 16)] {
            let c = NmCompressed::compress(&x, t, din, &[], n, m);
            let xd = c.decompress();
            // decompressed equals mask-pruned x
            for (r, row) in xd.chunks_exact(din).enumerate() {
                let pr = crate::sparsity::mask::nm_prune(
                    &x[r * din..(r + 1) * din],
                    &[],
                    n,
                    m,
                );
                assert_eq!(row, &pr[..]);
            }
            // compressed matmul == dense matmul over pruned x
            let y_sparse = c.matmul(&w, dout);
            let y_dense = dense_matmul(&xd, t, din, &w, dout);
            for (a, b) in y_sparse.iter().zip(y_dense.iter()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn flops_ratio() {
        let c = NmCompressed {
            t: 4,
            din: 16,
            n: 2,
            m: 4,
            values: vec![0.0; 4 * 8],
            index: vec![0; 4 * 8],
        };
        let s = c.stats(10);
        assert_eq!(s.sparse_flops * 2, s.dense_flops);
    }

    #[test]
    #[should_panic(expected = "not divisible by the N:M group")]
    fn compress_rejects_ragged_din() {
        // din = 10 is not a multiple of m = 4: must fail up front with a
        // clear message, not deep inside the mask kernel
        let x = vec![1.0f32; 2 * 10];
        NmCompressed::compress(&x, 2, 10, &[], 2, 4);
    }

    #[test]
    #[should_panic(expected = "malformed N:M ratio")]
    fn compress_rejects_n_above_m() {
        let x = vec![1.0f32; 8];
        NmCompressed::compress(&x, 1, 8, &[], 6, 4);
    }

    #[test]
    #[should_panic(expected = "malformed N:M ratio")]
    fn compress_rejects_zero_n() {
        let x = vec![1.0f32; 8];
        NmCompressed::compress(&x, 1, 8, &[], 0, 4);
    }

    #[test]
    fn batch_compress_matches_per_row_bitwise() {
        // block-compressed layout == per-row layout, for every ratio and
        // a block height that does NOT divide t (exercises the tail tile)
        let mut rng = Rng::new(3);
        let (t, din, dout) = (11, 32, 8);
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        for &(n, m) in &[(2usize, 4usize), (4, 8), (8, 16)] {
            let per_row = NmCompressed::compress(&x, t, din, &[], n, m);
            let batch =
                NmCompressedBatch::compress(&x, t, din, &[], n, m, 4);
            assert_eq!(batch.n_blocks(), 3);
            assert_eq!(batch.decompress(), per_row.decompress());
            let y_row = per_row.matmul(&w, dout);
            assert_eq!(batch.matmul(&w, dout), y_row, "{n}:{m} serial");
            let wa = Arc::new(w.clone());
            for width in [1usize, 2, 4] {
                let pool = ThreadPool::new(width);
                assert_eq!(
                    batch.matmul_parallel(&wa, dout, &pool),
                    y_row,
                    "{n}:{m} pool {width}"
                );
            }
        }
    }

    #[test]
    fn batch_zero_rows_is_empty() {
        let b = NmCompressedBatch::compress(&[], 0, 16, &[], 2, 4, 8);
        assert_eq!(b.n_blocks(), 0);
        assert!(b.decompress().is_empty());
        assert!(b.matmul(&vec![0.0; 16 * 4], 4).is_empty());
    }

    #[test]
    fn dense_parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(7);
        let (t, din, dout) = (13, 16, 8);
        let x = Arc::new(rand_mat(&mut rng, t * din));
        let w = Arc::new(rand_mat(&mut rng, din * dout));
        let serial = dense_matmul(&x, t, din, &w, dout);
        for width in [1usize, 2, 4] {
            let pool = ThreadPool::new(width);
            for tile in [1usize, 3, 8] {
                assert_eq!(
                    dense_matmul_parallel(
                        &x, t, din, &w, dout, &pool, 4, tile
                    ),
                    serial,
                    "pool {width} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn packed_paths_match_row_major_bitwise() {
        // serial + parallel packed SpMM and dense against every panel
        // width must reproduce the row-major kernels bit for bit
        let mut rng = Rng::new(11);
        let (t, din, dout) = (11usize, 32usize, 21usize);
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        let xa = Arc::new(x.clone());
        let dense_golden = dense_matmul(&x, t, din, &w, dout);
        for &pw in &[1usize, 8, 16, 64] {
            let packed = Arc::new(PackedPanels::pack(&w, din, dout, pw));
            assert_eq!(
                dense_matmul_packed(&x, t, din, &packed),
                dense_golden,
                "dense pw {pw}"
            );
            for &width in &[1usize, 4] {
                let pool = ThreadPool::new(width);
                assert_eq!(
                    dense_matmul_packed_parallel(
                        &xa, t, din, &packed, &pool, 4
                    ),
                    dense_golden,
                    "dense pw {pw} pool {width}"
                );
            }
            for &(n, m) in &[(2usize, 4usize), (4, 8)] {
                let c = NmCompressed::compress(&x, t, din, &[], n, m);
                let golden = c.matmul(&w, dout);
                assert_eq!(
                    c.matmul_packed(&packed),
                    golden,
                    "{n}:{m} pw {pw} per-row"
                );
                let batch = NmCompressedBatch::compress(
                    &x, t, din, &[], n, m, 4,
                );
                assert_eq!(
                    batch.matmul_packed(&packed),
                    golden,
                    "{n}:{m} pw {pw} batch"
                );
                for &width in &[1usize, 4] {
                    let pool = ThreadPool::new(width);
                    assert_eq!(
                        batch.matmul_packed_parallel(&packed, &pool),
                        golden,
                        "{n}:{m} pw {pw} pool {width}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_baseline_does_full_work_on_pruned_input() {
        // regression pin for bench fairness: the dense baseline must do
        // t*din channel-row operations even when the input is N:M-pruned,
        // while the skip-zeros variant does only the surviving share.
        let mut rng = Rng::new(9);
        let (t, din, dout) = (4, 32, 8);
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        let pruned = NmCompressed::compress(&x, t, din, &[], 2, 4)
            .decompress();
        let (y_full, ops_full) =
            dense_matmul_counted(&pruned, t, din, &w, dout, false);
        let (y_skip, ops_skip) =
            dense_matmul_counted(&pruned, t, din, &w, dout, true);
        assert_eq!(ops_full, (t * din) as u64);
        assert_eq!(ops_skip, (t * din / 2) as u64); // exactly 2:4 survive
        // same math either way
        for (a, b) in y_full.iter().zip(y_skip.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
        // and the public entry points agree with the counted kernel
        assert_eq!(dense_matmul(&pruned, t, din, &w, dout), y_full);
        assert_eq!(
            dense_matmul_skip_zeros(&pruned, t, din, &w, dout),
            y_skip
        );
    }
}
