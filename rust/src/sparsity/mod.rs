//! N:M sparsity substrate on the rust side.
//!
//! * `mask`      — exact N:M mask construction + validity checks (mirrors
//!                 the python kernels; used for verification and property
//!                 tests against vectors emitted at build time)
//! * `spmm`      — a native compressed N:M sparse x dense matmul; this is
//!                 the CPU stand-in for the paper's SpMM hardware and what
//!                 `cargo bench --bench spmm` measures (the N/M compute
//!                 scaling the paper's accelerator would deliver). The
//!                 block-compressed [`spmm::NmCompressedBatch`] variant
//!                 compresses a whole activation batch once and tiles the
//!                 SpMM over the engine thread pool
//! * `plan`      — the per-layer/per-projection [`plan::SparsityPlan`]
//!                 that decides dense-vs-N:M (and the ratio) for one
//!                 prefill, built from `coverage::Geometry` + `policy`
//! * `coverage`  — GQA-aware accounting of the fraction of linear-layer
//!                 FLOPs routed through the sparse path (the paper's
//!                 ">55% of linear computations accelerated" headline)
//! * `policy`    — the layer-skipping policy table (which module types are
//!                 prunable, mirroring the paper's setup section)

pub mod coverage;
pub mod estimate;
pub mod mask;
pub mod plan;
pub mod policy;
pub mod spmm;

pub use mask::{nm_mask_scored, nm_prune, validate_nm};
pub use plan::{ProjPolicy, SparsityPlan};
pub use spmm::{NmCompressed, NmCompressedBatch, SpmmStats};
