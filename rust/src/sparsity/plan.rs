//! Per-projection sparsity planning.
//!
//! A [`SparsityPlan`] is the explicit, precomputed answer to "what does
//! each linear projection of each layer do for this prefill?": stay
//! dense, or compress activations at some N:M ratio (optionally with
//! Robust-Norm channel scoring). It is built once per (model, ratio,
//! setting) from the policy table ([`super::policy`]) and the model's
//! skip-layer list, then threaded scheduler → engine → kernel — replacing
//! the ad-hoc `(nm, setting)` flag-juggling the runtime used to re-derive
//! inside every projection call.
//!
//! The plan also carries its own coverage accounting against a
//! [`Geometry`] (the paper's ">55% of linear computation sparsified"
//! headline), so serving, audits and the repro tables all report from the
//! same source of truth.
//!
//! Since the bind-time weight-preparation layer, the plan additionally
//! carries a per-module **tile table** ([`TileTable`]): the `dout`-tile
//! width each projection's kernels run at, planned from the model
//! geometry (narrow panels for `kv_dim`-sized outputs, wide for `d_ff`
//! and the vocab head) and stamped into each packed weight at
//! preparation time. Tile width is a pure performance knob — outputs
//! are bitwise identical for every width ([`crate::kernels`]).

use std::collections::BTreeMap;

use super::coverage::Geometry;
use super::policy::{self, Setting, MODULES};
use crate::kernels::{clamp_tile, DEFAULT_DOUT_TILE};

/// The planned `dout`-tile (= weight panel) width for a projection with
/// `dout` output columns: always one of the const-specialized kernel
/// widths (4/8/16/32), chosen so narrow projections (`kv_dim`-sized)
/// still split into several panels while wide ones (`d_ff`, vocab) get
/// the widest register tile. The exact cutoffs are a heuristic; the
/// parity suite pins that any choice yields identical bits.
pub fn planned_tile(dout: usize) -> usize {
    match dout {
        0..=7 => 4,
        8..=31 => 8,
        32..=127 => 16,
        _ => 32,
    }
}

/// [`planned_tile`] made lane-aware: the planned width, widened to at
/// least `lanes` (the f32 lane count of the resolved SIMD dispatch
/// level, a power of two `<= 16`). Because the specialized widths
/// (4/8/16/32) and the lane counts (1/4/8/16) are all powers of two,
/// `max` alone guarantees the result is a whole multiple of `lanes` —
/// full panels then carry no scalar tail under the vector kernels —
/// while staying one of the const-specialized widths. Purely a
/// performance refinement: parity holds at every width regardless.
pub fn planned_tile_for_lanes(dout: usize, lanes: usize) -> usize {
    clamp_tile(planned_tile(dout).max(lanes))
}

/// Per-module `dout`-tile widths: one entry per policy module
/// ([`policy::MODULES`]) plus the lm_head, with a fallback for modules
/// the table does not know. Planned from [`Geometry`] via
/// [`TileTable::plan`], or uniform via [`TileTable::uniform`] (the
/// engine-global override). Equality/hash are derived so re-binds can
/// detect an unchanged table and the engine can key prepared weights
/// by it.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TileTable {
    widths: [usize; MODULES.len()],
    /// the lm_head / logits projection width
    pub lm_head: usize,
    /// width for modules the table does not cover
    pub fallback: usize,
}

impl TileTable {
    /// Every module at the same (clamped) width — the engine-global
    /// `dout_tile` override, and the pre-planning default.
    pub fn uniform(w: usize) -> TileTable {
        let w = clamp_tile(w);
        TileTable {
            widths: [w; MODULES.len()],
            lm_head: w,
            fallback: w,
        }
    }

    /// Plan per-module widths from the geometry: each module's width is
    /// [`planned_tile`] of its output dimension (`vocab` sizes the
    /// lm_head panel).
    pub fn plan(g: &Geometry, vocab: usize) -> TileTable {
        TileTable::plan_for_lanes(g, vocab, 1)
    }

    /// [`TileTable::plan`] widened for a SIMD dispatch level: every
    /// planned width is [`planned_tile_for_lanes`] of the module's
    /// output dimension, so full panels are whole vector registers at
    /// the level the binding resolved (`lanes` = `Level::lanes_f32`).
    /// With `lanes == 1` this is exactly [`TileTable::plan`].
    pub fn plan_for_lanes(
        g: &Geometry,
        vocab: usize,
        lanes: usize,
    ) -> TileTable {
        let dout_of = |name: &str| match name {
            "q_proj" => g.q_dim,
            "k_proj" | "v_proj" => g.kv_dim,
            "o_proj" | "down_proj" => g.d_model,
            "gate_proj" | "up_proj" => {
                if g.is_moe() {
                    g.d_ff_expert
                } else {
                    g.d_ff
                }
            }
            _ => g.d_model,
        };
        let mut widths = [DEFAULT_DOUT_TILE; MODULES.len()];
        for (mi, name) in MODULES.iter().enumerate() {
            widths[mi] = planned_tile_for_lanes(dout_of(name), lanes);
        }
        TileTable {
            widths,
            lm_head: planned_tile_for_lanes(vocab, lanes),
            fallback: planned_tile_for_lanes(DEFAULT_DOUT_TILE, lanes),
        }
    }

    /// The planned width for `module` ("q_proj", ..., "lm_head");
    /// unknown modules get the fallback width.
    pub fn tile_for(&self, module: &str) -> usize {
        if module == "lm_head" {
            return self.lm_head;
        }
        match policy::module_index(module) {
            Some(mi) => self.widths[mi],
            None => self.fallback,
        }
    }
}

impl Default for TileTable {
    fn default() -> TileTable {
        TileTable::uniform(DEFAULT_DOUT_TILE)
    }
}

/// What one projection in one layer does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProjPolicy {
    /// N:M ratio to compress the activation with; `None` = dense.
    pub nm: Option<(usize, usize)>,
    /// Use Robust-Norm channel scores (the `all` setting) rather than
    /// naive magnitude scoring.
    pub scored: bool,
}

impl ProjPolicy {
    /// The dense decision (no compression, no scoring).
    pub const DENSE: ProjPolicy = ProjPolicy { nm: None, scored: false };

    /// Whether this projection compresses its activation at all.
    pub fn is_sparse(&self) -> bool {
        self.nm.is_some()
    }
}

/// The full per-layer/per-projection decision table for one prefill.
#[derive(Debug, Clone)]
pub struct SparsityPlan {
    /// the policy setting the plan was built from
    pub setting: Setting,
    /// the plan's N:M ratio (`None` = dense plan)
    pub nm: Option<(usize, usize)>,
    /// per-module tile table the binding's weights were packed with —
    /// stamped at bind time ([`TileTable::plan`] from the geometry, or
    /// uniform under the engine-global override) and threaded through
    /// `ExecOpts` into prefill, decode and logits. A pure performance
    /// knob: outputs are bitwise identical for every width
    /// ([`crate::kernels`]).
    pub tiles: TileTable,
    /// `cells[layer][module_index]` over [`policy::MODULES`].
    cells: Vec<[ProjPolicy; MODULES.len()]>,
}

impl SparsityPlan {
    /// The all-dense plan (dense artifacts, decode, lm_head-only paths).
    pub fn dense(n_layers: usize) -> SparsityPlan {
        SparsityPlan::build(n_layers, &[], None, Setting::Dense)
    }

    /// Build the plan for `n_layers` transformer layers under the paper's
    /// policy: `nm = None` or `setting == Dense` yields the dense plan;
    /// `Naive` prunes every policy-prunable module in every layer;
    /// `LayerSkip`/`All` additionally keep q/gate dense in `skip_layers`,
    /// and `All` turns on Robust-Norm scoring.
    pub fn build(
        n_layers: usize,
        skip_layers: &[usize],
        nm: Option<(usize, usize)>,
        setting: Setting,
    ) -> SparsityPlan {
        let mut cells = vec![[ProjPolicy::DENSE; MODULES.len()]; n_layers];
        if let Some((n, m)) = nm {
            if setting != Setting::Dense {
                let skips: &[usize] = match setting {
                    Setting::Naive => &[],
                    _ => skip_layers,
                };
                let scored = setting == Setting::All;
                for (layer, row) in cells.iter_mut().enumerate() {
                    for (mi, name) in MODULES.iter().enumerate() {
                        if policy::pruned_in_layer(name, layer, skips) {
                            row[mi] =
                                ProjPolicy { nm: Some((n, m)), scored };
                        }
                    }
                }
            }
        }
        SparsityPlan { setting, nm, tiles: TileTable::default(), cells }
    }

    /// Set a uniform kernel `dout`-tile width (clamped to the supported
    /// range) — collapses the tile table to that width. Pure perf: the
    /// parity suite pins that every width yields bitwise-identical
    /// outputs.
    pub fn with_dout_tile(mut self, dout_tile: usize) -> SparsityPlan {
        self.tiles = TileTable::uniform(dout_tile);
        self
    }

    /// Stamp the per-module tile table the binding's weights are packed
    /// with (see [`TileTable::plan`]).
    pub fn with_tiles(mut self, tiles: TileTable) -> SparsityPlan {
        self.tiles = tiles;
        self
    }

    /// Build for a [`Geometry`] (uses its layer count).
    pub fn for_geometry(
        g: &Geometry,
        skip_layers: &[usize],
        nm: Option<(usize, usize)>,
        setting: Setting,
    ) -> SparsityPlan {
        SparsityPlan::build(g.n_layers, skip_layers, nm, setting)
    }

    /// Layers the plan covers.
    pub fn n_layers(&self) -> usize {
        self.cells.len()
    }

    /// Decision for `module` ("q_proj", ...) in `layer`. Unknown modules
    /// (e.g. "lm_head") and out-of-range layers are dense — the safe
    /// default for everything the policy table does not cover.
    pub fn policy(&self, layer: usize, module: &str) -> ProjPolicy {
        match (self.cells.get(layer), policy::module_index(module)) {
            (Some(row), Some(mi)) => row[mi],
            _ => ProjPolicy::DENSE,
        }
    }

    /// Any projection sparse at all?
    pub fn is_sparse(&self) -> bool {
        self.cells
            .iter()
            .any(|row| row.iter().any(|p| p.is_sparse()))
    }

    /// Fraction of per-token linear FLOPs this plan routes through the
    /// N:M path under geometry `g` (the paper's coverage headline,
    /// computed from the actual decision table rather than re-deriving
    /// the policy).
    pub fn coverage(&self, g: &Geometry) -> f64 {
        let fl = g.module_flops();
        let mut total = 0u64;
        let mut pruned = 0u64;
        for row in &self.cells {
            for (mi, name) in MODULES.iter().enumerate() {
                let f = fl.get(name).copied().unwrap_or(0);
                total += f;
                if row[mi].is_sparse() {
                    pruned += f;
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        pruned as f64 / total as f64
    }

    /// Per-module coverage: module name -> fraction of that module's
    /// layers that are sparse under the plan.
    pub fn module_coverage(&self) -> BTreeMap<&'static str, f64> {
        let n = self.n_layers().max(1) as f64;
        MODULES
            .iter()
            .enumerate()
            .map(|(mi, name)| {
                let sparse = self
                    .cells
                    .iter()
                    .filter(|row| row[mi].is_sparse())
                    .count();
                (*name, sparse as f64 / n)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_plan_is_all_dense() {
        let p = SparsityPlan::dense(4);
        assert!(!p.is_sparse());
        assert_eq!(p.policy(2, "down_proj"), ProjPolicy::DENSE);
        // nm set but setting dense still means dense
        let p2 = SparsityPlan::build(4, &[], Some((2, 4)), Setting::Dense);
        assert!(!p2.is_sparse());
    }

    #[test]
    fn plan_matches_policy_table() {
        let skips = [1usize];
        let p =
            SparsityPlan::build(3, &skips, Some((4, 8)), Setting::LayerSkip);
        for layer in 0..3 {
            for name in MODULES {
                let want =
                    policy::pruned_in_layer(name, layer, &skips);
                let got = p.policy(layer, name);
                assert_eq!(got.is_sparse(), want, "{name} layer {layer}");
                if want {
                    assert_eq!(got.nm, Some((4, 8)));
                    assert!(!got.scored, "ls setting must not score");
                }
            }
        }
        // naive ignores the skip list; all turns on scoring
        let naive =
            SparsityPlan::build(3, &skips, Some((2, 4)), Setting::Naive);
        assert!(naive.policy(1, "q_proj").is_sparse());
        let all = SparsityPlan::build(3, &skips, Some((2, 4)), Setting::All);
        assert!(all.policy(0, "q_proj").scored);
        assert!(!all.policy(1, "q_proj").is_sparse());
    }

    #[test]
    fn dout_tile_knob_defaults_and_clamps() {
        let p = SparsityPlan::dense(2);
        assert_eq!(p.tiles, TileTable::uniform(DEFAULT_DOUT_TILE));
        let tile = |p: &SparsityPlan| p.tiles.tile_for("q_proj");
        assert_eq!(tile(&p.clone().with_dout_tile(0)), 1);
        assert_eq!(tile(&p.clone().with_dout_tile(16)), 16);
        assert_eq!(
            tile(&p.with_dout_tile(usize::MAX)),
            crate::kernels::MAX_DOUT_TILE
        );
    }

    #[test]
    fn tile_table_plans_per_module_widths() {
        let g = Geometry {
            d_model: 32,
            n_layers: 2,
            q_dim: 32,
            kv_dim: 16,
            d_ff: 256,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        };
        let t = TileTable::plan(&g, 384);
        // kv_dim-sized outputs get narrow panels, d_ff/vocab wide ones
        assert_eq!(t.tile_for("k_proj"), 8);
        assert_eq!(t.tile_for("v_proj"), 8);
        assert_eq!(t.tile_for("q_proj"), 16);
        assert_eq!(t.tile_for("o_proj"), 16);
        assert_eq!(t.tile_for("down_proj"), 16);
        assert_eq!(t.tile_for("gate_proj"), 32);
        assert_eq!(t.tile_for("up_proj"), 32);
        assert_eq!(t.tile_for("lm_head"), 32);
        assert_eq!(t.tile_for("mystery"), DEFAULT_DOUT_TILE);
        // uniform override collapses everything, clamped
        let u = TileTable::uniform(0);
        assert_eq!(u.tile_for("gate_proj"), 1);
        assert_eq!(u.tile_for("lm_head"), 1);
        // with_dout_tile keeps plan.tiles consistent with the knob
        let p = SparsityPlan::dense(2).with_dout_tile(16);
        assert_eq!(p.tiles, TileTable::uniform(16));
        // with_tiles stamps a planned table verbatim
        let p2 = SparsityPlan::dense(2).with_tiles(t.clone());
        assert_eq!(p2.tiles, t);
    }

    #[test]
    fn planned_tile_uses_specialized_widths_only() {
        for dout in 1usize..400 {
            let w = planned_tile(dout);
            assert!(
                [4usize, 8, 16, 32].contains(&w),
                "dout {dout} planned non-specialized width {w}"
            );
        }
        assert_eq!(planned_tile(16), 8);
        assert_eq!(planned_tile(384), 32);
    }

    #[test]
    fn lane_aware_planning_rounds_to_whole_registers() {
        // every lane count keeps widths specialized AND lane-multiple
        for lanes in [1usize, 4, 8, 16] {
            for dout in 1usize..400 {
                let w = planned_tile_for_lanes(dout, lanes);
                assert!(
                    [4usize, 8, 16, 32].contains(&w),
                    "dout {dout} lanes {lanes}: width {w}"
                );
                assert_eq!(w % lanes, 0, "dout {dout} lanes {lanes}");
                assert!(w >= planned_tile(dout), "never narrows");
            }
        }
        // lanes == 1 is exactly the scalar plan
        assert_eq!(planned_tile_for_lanes(16, 1), planned_tile(16));
        // a 16-lane register widens the narrow kv panels to one register
        let g = Geometry {
            d_model: 32,
            n_layers: 2,
            q_dim: 32,
            kv_dim: 16,
            d_ff: 256,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        };
        let t = TileTable::plan_for_lanes(&g, 384, 16);
        assert_eq!(t.tile_for("k_proj"), 16);
        assert_eq!(t.tile_for("gate_proj"), 32);
        assert_eq!(TileTable::plan_for_lanes(&g, 384, 1), TileTable::plan(&g, 384));
    }

    #[test]
    fn unknown_module_and_layer_are_dense() {
        let p = SparsityPlan::build(2, &[], Some((2, 4)), Setting::Naive);
        assert_eq!(p.policy(0, "lm_head"), ProjPolicy::DENSE);
        assert_eq!(p.policy(99, "down_proj"), ProjPolicy::DENSE);
    }

    #[test]
    fn coverage_agrees_with_geometry_coverage() {
        let g = Geometry {
            d_model: 96,
            n_layers: 6,
            q_dim: 96,
            kv_dim: 32,
            d_ff: 384,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        };
        let skips = [5usize];
        let p = SparsityPlan::for_geometry(
            &g,
            &skips,
            Some((2, 4)),
            Setting::LayerSkip,
        );
        let want = g.coverage(&skips);
        assert!((p.coverage(&g) - want).abs() < 1e-12);
        assert!(p.coverage(&g) > 0.55);
        // per-module: down is pruned everywhere, o never
        let mc = p.module_coverage();
        assert_eq!(mc["down_proj"], 1.0);
        assert_eq!(mc["o_proj"], 0.0);
        assert!((mc["q_proj"] - 5.0 / 6.0).abs() < 1e-12);
    }
}
