//! Per-projection sparsity planning.
//!
//! A [`SparsityPlan`] is the explicit, precomputed answer to "what does
//! each linear projection of each layer do for this prefill?": stay
//! dense, or compress activations at some N:M ratio (optionally with
//! Robust-Norm channel scoring). It is built once per (model, ratio,
//! setting) from the policy table ([`super::policy`]) and the model's
//! skip-layer list, then threaded scheduler → engine → kernel — replacing
//! the ad-hoc `(nm, setting)` flag-juggling the runtime used to re-derive
//! inside every projection call.
//!
//! The plan also carries its own coverage accounting against a
//! [`Geometry`] (the paper's ">55% of linear computation sparsified"
//! headline), so serving, audits and the repro tables all report from the
//! same source of truth.

use std::collections::BTreeMap;

use super::coverage::Geometry;
use super::policy::{self, Setting, MODULES};
use crate::kernels::{clamp_tile, DEFAULT_DOUT_TILE};

/// What one projection in one layer does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProjPolicy {
    /// N:M ratio to compress the activation with; `None` = dense.
    pub nm: Option<(usize, usize)>,
    /// Use Robust-Norm channel scores (the `all` setting) rather than
    /// naive magnitude scoring.
    pub scored: bool,
}

impl ProjPolicy {
    /// The dense decision (no compression, no scoring).
    pub const DENSE: ProjPolicy = ProjPolicy { nm: None, scored: false };

    /// Whether this projection compresses its activation at all.
    pub fn is_sparse(&self) -> bool {
        self.nm.is_some()
    }
}

/// The full per-layer/per-projection decision table for one prefill.
#[derive(Debug, Clone)]
pub struct SparsityPlan {
    /// the policy setting the plan was built from
    pub setting: Setting,
    /// the plan's N:M ratio (`None` = dense plan)
    pub nm: Option<(usize, usize)>,
    /// `dout`-tile width every projection kernel of this plan runs at
    /// (a pure performance knob — outputs are bitwise identical for
    /// every width; see [`crate::kernels`]). Defaults to
    /// [`crate::kernels::DEFAULT_DOUT_TILE`].
    pub dout_tile: usize,
    /// `cells[layer][module_index]` over [`policy::MODULES`].
    cells: Vec<[ProjPolicy; MODULES.len()]>,
}

impl SparsityPlan {
    /// The all-dense plan (dense artifacts, decode, lm_head-only paths).
    pub fn dense(n_layers: usize) -> SparsityPlan {
        SparsityPlan::build(n_layers, &[], None, Setting::Dense)
    }

    /// Build the plan for `n_layers` transformer layers under the paper's
    /// policy: `nm = None` or `setting == Dense` yields the dense plan;
    /// `Naive` prunes every policy-prunable module in every layer;
    /// `LayerSkip`/`All` additionally keep q/gate dense in `skip_layers`,
    /// and `All` turns on Robust-Norm scoring.
    pub fn build(
        n_layers: usize,
        skip_layers: &[usize],
        nm: Option<(usize, usize)>,
        setting: Setting,
    ) -> SparsityPlan {
        let mut cells = vec![[ProjPolicy::DENSE; MODULES.len()]; n_layers];
        if let Some((n, m)) = nm {
            if setting != Setting::Dense {
                let skips: &[usize] = match setting {
                    Setting::Naive => &[],
                    _ => skip_layers,
                };
                let scored = setting == Setting::All;
                for (layer, row) in cells.iter_mut().enumerate() {
                    for (mi, name) in MODULES.iter().enumerate() {
                        if policy::pruned_in_layer(name, layer, skips) {
                            row[mi] =
                                ProjPolicy { nm: Some((n, m)), scored };
                        }
                    }
                }
            }
        }
        SparsityPlan { setting, nm, dout_tile: DEFAULT_DOUT_TILE, cells }
    }

    /// Set the kernel `dout`-tile width (clamped to the supported
    /// range). Pure perf: the parity suite pins that every width yields
    /// bitwise-identical outputs.
    pub fn with_dout_tile(mut self, dout_tile: usize) -> SparsityPlan {
        self.dout_tile = clamp_tile(dout_tile);
        self
    }

    /// Build for a [`Geometry`] (uses its layer count).
    pub fn for_geometry(
        g: &Geometry,
        skip_layers: &[usize],
        nm: Option<(usize, usize)>,
        setting: Setting,
    ) -> SparsityPlan {
        SparsityPlan::build(g.n_layers, skip_layers, nm, setting)
    }

    /// Layers the plan covers.
    pub fn n_layers(&self) -> usize {
        self.cells.len()
    }

    /// Decision for `module` ("q_proj", ...) in `layer`. Unknown modules
    /// (e.g. "lm_head") and out-of-range layers are dense — the safe
    /// default for everything the policy table does not cover.
    pub fn policy(&self, layer: usize, module: &str) -> ProjPolicy {
        match (self.cells.get(layer), policy::module_index(module)) {
            (Some(row), Some(mi)) => row[mi],
            _ => ProjPolicy::DENSE,
        }
    }

    /// Any projection sparse at all?
    pub fn is_sparse(&self) -> bool {
        self.cells
            .iter()
            .any(|row| row.iter().any(|p| p.is_sparse()))
    }

    /// Fraction of per-token linear FLOPs this plan routes through the
    /// N:M path under geometry `g` (the paper's coverage headline,
    /// computed from the actual decision table rather than re-deriving
    /// the policy).
    pub fn coverage(&self, g: &Geometry) -> f64 {
        let fl = g.module_flops();
        let mut total = 0u64;
        let mut pruned = 0u64;
        for row in &self.cells {
            for (mi, name) in MODULES.iter().enumerate() {
                let f = fl.get(name).copied().unwrap_or(0);
                total += f;
                if row[mi].is_sparse() {
                    pruned += f;
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        pruned as f64 / total as f64
    }

    /// Per-module coverage: module name -> fraction of that module's
    /// layers that are sparse under the plan.
    pub fn module_coverage(&self) -> BTreeMap<&'static str, f64> {
        let n = self.n_layers().max(1) as f64;
        MODULES
            .iter()
            .enumerate()
            .map(|(mi, name)| {
                let sparse = self
                    .cells
                    .iter()
                    .filter(|row| row[mi].is_sparse())
                    .count();
                (*name, sparse as f64 / n)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_plan_is_all_dense() {
        let p = SparsityPlan::dense(4);
        assert!(!p.is_sparse());
        assert_eq!(p.policy(2, "down_proj"), ProjPolicy::DENSE);
        // nm set but setting dense still means dense
        let p2 = SparsityPlan::build(4, &[], Some((2, 4)), Setting::Dense);
        assert!(!p2.is_sparse());
    }

    #[test]
    fn plan_matches_policy_table() {
        let skips = [1usize];
        let p =
            SparsityPlan::build(3, &skips, Some((4, 8)), Setting::LayerSkip);
        for layer in 0..3 {
            for name in MODULES {
                let want =
                    policy::pruned_in_layer(name, layer, &skips);
                let got = p.policy(layer, name);
                assert_eq!(got.is_sparse(), want, "{name} layer {layer}");
                if want {
                    assert_eq!(got.nm, Some((4, 8)));
                    assert!(!got.scored, "ls setting must not score");
                }
            }
        }
        // naive ignores the skip list; all turns on scoring
        let naive =
            SparsityPlan::build(3, &skips, Some((2, 4)), Setting::Naive);
        assert!(naive.policy(1, "q_proj").is_sparse());
        let all = SparsityPlan::build(3, &skips, Some((2, 4)), Setting::All);
        assert!(all.policy(0, "q_proj").scored);
        assert!(!all.policy(1, "q_proj").is_sparse());
    }

    #[test]
    fn dout_tile_knob_defaults_and_clamps() {
        let p = SparsityPlan::dense(2);
        assert_eq!(p.dout_tile, DEFAULT_DOUT_TILE);
        assert_eq!(p.clone().with_dout_tile(0).dout_tile, 1);
        assert_eq!(p.clone().with_dout_tile(16).dout_tile, 16);
        assert_eq!(
            p.with_dout_tile(usize::MAX).dout_tile,
            crate::kernels::MAX_DOUT_TILE
        );
    }

    #[test]
    fn unknown_module_and_layer_are_dense() {
        let p = SparsityPlan::build(2, &[], Some((2, 4)), Setting::Naive);
        assert_eq!(p.policy(0, "lm_head"), ProjPolicy::DENSE);
        assert_eq!(p.policy(99, "down_proj"), ProjPolicy::DENSE);
    }

    #[test]
    fn coverage_agrees_with_geometry_coverage() {
        let g = Geometry {
            d_model: 96,
            n_layers: 6,
            q_dim: 96,
            kv_dim: 32,
            d_ff: 384,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        };
        let skips = [5usize];
        let p = SparsityPlan::for_geometry(
            &g,
            &skips,
            Some((2, 4)),
            Setting::LayerSkip,
        );
        let want = g.coverage(&skips);
        assert!((p.coverage(&g) - want).abs() < 1e-12);
        assert!(p.coverage(&g) > 0.55);
        // per-module: down is pruned everywhere, o never
        let mc = p.module_coverage();
        assert_eq!(mc["down_proj"], 1.0);
        assert_eq!(mc["o_proj"], 0.0);
        assert!((mc["q_proj"] - 5.0 / 6.0).abs() < 1e-12);
    }
}
