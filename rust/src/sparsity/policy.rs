//! The paper's layer-skipping policy, as a rust-side table (mirrors
//! `amber/sensitivity.py`; the actual keep_dense tensors ship as aux
//! weights — this module is for accounting, display and serving-config
//! validation).

/// The seven per-layer projection module types, in aux-tensor order.
pub const MODULES: [&str; 7] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj",
    "down_proj",
];

/// Index of a module name in the aux keep_dense layout.
pub fn module_index(name: &str) -> Option<usize> {
    MODULES.iter().position(|m| *m == name)
}

/// Module types that may ever be pruned (paper §Experimental Setup):
/// k/v are non-prunable under GQA (negligible FLOPs), o/up are preserved
/// (highest sensitivity), down is always pruned, q/gate selectively.
pub fn prunable(name: &str) -> bool {
    matches!(name, "q_proj" | "gate_proj" | "down_proj")
}

/// Whether a module is pruned in a given layer under the policy.
pub fn pruned_in_layer(name: &str, layer: usize, skip_layers: &[usize]) -> bool {
    match name {
        "down_proj" => true,
        "q_proj" | "gate_proj" => !skip_layers.contains(&layer),
        _ => false,
    }
}

/// The three Table-1 settings and the dense baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Setting {
    /// no pruning anywhere (the dense baseline)
    Dense,
    /// magnitude top-k everywhere, no skipping (the paper's baseline)
    Naive,
    /// + layer skipping ("Amber-P (l.s.)")
    LayerSkip,
    /// + Robust-Norm Scoring ("Amber-P (all)"; dense models only)
    All,
}

impl Setting {
    /// The aux weight-file name that carries this setting.
    pub fn aux_file(&self, model: &str, sq: bool) -> String {
        let infix = if sq { ".sq" } else { "" };
        let tag = match self {
            Setting::Dense => "dense",
            Setting::Naive => "naive",
            Setting::LayerSkip => "ls",
            Setting::All => "all",
        };
        format!("{model}{infix}.aux_{tag}.atw")
    }

    /// The paper's display label for this setting.
    pub fn label(&self) -> &'static str {
        match self {
            Setting::Dense => "Baseline",
            Setting::Naive => "Naive top-k",
            Setting::LayerSkip => "Amber-P (l.s.)",
            Setting::All => "Amber-P (all)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_table() {
        assert!(pruned_in_layer("down_proj", 3, &[3]));
        assert!(!pruned_in_layer("q_proj", 3, &[3]));
        assert!(pruned_in_layer("q_proj", 2, &[3]));
        assert!(!pruned_in_layer("o_proj", 0, &[]));
        assert!(!prunable("k_proj"));
    }

    #[test]
    fn aux_names() {
        assert_eq!(
            Setting::All.aux_file("tiny-lm-a", false),
            "tiny-lm-a.aux_all.atw"
        );
        assert_eq!(
            Setting::Naive.aux_file("tiny-lm-b", true),
            "tiny-lm-b.sq.aux_naive.atw"
        );
    }
}
