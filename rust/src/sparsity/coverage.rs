//! FLOPs coverage accounting — the paper's headline efficiency metric
//! ("Amber Pruner accelerates over 55% of linear projection computation").
//!
//! Counts per-token matmul FLOPs (2 * d_in * d_out) of every linear module
//! and the fraction routed through the N:M path under a skip policy. For
//! MoE models the expert MLP counts activated experts only (top-k), the
//! same accounting the paper applies to Qwen3-30B-*A3B*.

use std::collections::BTreeMap;

use super::policy;

/// Minimal model geometry (parsed from manifest config).
#[derive(Debug, Clone)]
pub struct Geometry {
    /// model width
    pub d_model: usize,
    /// transformer layers
    pub n_layers: usize,
    /// query projection width (heads x head_dim)
    pub q_dim: usize,
    /// key/value projection width
    pub kv_dim: usize,
    /// MLP hidden width (dense models)
    pub d_ff: usize,
    /// expert count (0 = dense model)
    pub n_experts: usize,
    /// activated experts per token
    pub top_k: usize,
    /// per-expert MLP hidden width
    pub d_ff_expert: usize,
}

impl Geometry {
    /// Geometry from a manifest model config (missing keys are 0).
    pub fn from_config(cfg: &BTreeMap<String, usize>) -> Geometry {
        let g = |k: &str| cfg.get(k).copied().unwrap_or(0);
        Geometry {
            d_model: g("d_model"),
            n_layers: g("n_layers"),
            q_dim: g("n_q_heads") * g("head_dim"),
            kv_dim: g("n_kv_heads") * g("head_dim"),
            d_ff: g("d_ff"),
            n_experts: g("n_experts"),
            top_k: g("top_k_experts"),
            d_ff_expert: g("d_ff_expert"),
        }
    }

    /// Whether the geometry describes a mixture-of-experts model.
    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Per-token FLOPs of each linear module type.
    pub fn module_flops(&self) -> BTreeMap<&'static str, u64> {
        let d = self.d_model as u64;
        let q = self.q_dim as u64;
        let kv = self.kv_dim as u64;
        let mut out = BTreeMap::new();
        out.insert("q_proj", 2 * d * q);
        out.insert("k_proj", 2 * d * kv);
        out.insert("v_proj", 2 * d * kv);
        out.insert("o_proj", 2 * q * d);
        if self.is_moe() {
            let k = self.top_k as u64;
            let fe = self.d_ff_expert as u64;
            out.insert("gate_proj", 2 * d * fe * k);
            out.insert("up_proj", 2 * d * fe * k);
            out.insert("down_proj", 2 * fe * d * k);
        } else {
            let f = self.d_ff as u64;
            out.insert("gate_proj", 2 * d * f);
            out.insert("up_proj", 2 * d * f);
            out.insert("down_proj", 2 * f * d);
        }
        out
    }

    /// Fraction of linear FLOPs pruned under the policy with the given
    /// per-layer q/gate skip list.
    pub fn coverage(&self, skip_layers: &[usize]) -> f64 {
        let fl = self.module_flops();
        let mut total = 0u64;
        let mut pruned = 0u64;
        for layer in 0..self.n_layers {
            for (m, f) in &fl {
                total += f;
                if policy::pruned_in_layer(m, layer, skip_layers) {
                    pruned += f;
                }
            }
        }
        pruned as f64 / total as f64
    }

    /// Effective speedup of the covered computation at ratio n/m assuming
    /// ideal SpMM hardware (Amdahl over the linear-layer fraction).
    pub fn ideal_linear_speedup(&self, skip_layers: &[usize], n: usize,
                                m: usize) -> f64 {
        let cov = self.coverage(skip_layers);
        1.0 / (1.0 - cov + cov * n as f64 / m as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_a() -> Geometry {
        Geometry {
            d_model: 96,
            n_layers: 6,
            q_dim: 96,
            kv_dim: 32,
            d_ff: 384,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        }
    }

    #[test]
    fn coverage_above_55_with_one_skip() {
        let g = tiny_a();
        let cov = g.coverage(&[5]);
        assert!(cov > 0.55, "coverage {cov}");
        assert!(cov < 0.60);
    }

    #[test]
    fn no_skip_higher_than_skip() {
        let g = tiny_a();
        assert!(g.coverage(&[]) > g.coverage(&[0]));
    }

    #[test]
    fn speedup_bounds() {
        let g = tiny_a();
        let s = g.ideal_linear_speedup(&[5], 2, 4);
        assert!(s > 1.0 && s < 2.0);
    }
}
