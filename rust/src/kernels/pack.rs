//! Tile-panel weight packing — the bind-time layout transform that
//! makes the register-tiled kernels stream weights unit-stride.
//!
//! # Why panels
//!
//! The row-major `[din, dout]` weight layout forces every `(row, tile)`
//! microkernel pass to stride by `dout` between consecutive contraction
//! steps: the `W`-wide weight row of channel `k` lives at
//! `k * dout + c0`, so two adjacent `k`s are `dout` floats apart. At
//! realistic `dout` that defeats the hardware prefetcher and turns the
//! tiled kernels memory-bound on weight traffic — the weight matrix is
//! streamed once per tile *column*, in `dout`-strided gulps.
//!
//! A [`PackedPanels`] stores the same matrix as **panels** of
//! `panel_w` output columns, each panel holding its `din` rows
//! contiguously:
//!
//! ```text
//! row-major  [din, dout]:            packed panels (panel_w = W):
//!   k0: c0 c1 c2 c3 c4 c5 ...          panel 0 (cols 0..W):
//!   k1: c0 c1 c2 c3 c4 c5 ...            k0: c0..cW   | unit
//!   ...                                   k1: c0..cW   | stride
//!                                         ...          v
//!                                       panel 1 (cols W..2W): ...
//!                                       last panel: ragged tail width
//! ```
//!
//! The inner kernel loop for panel `p` then reads
//! `panel[k * panel_w ..][..panel_w]` — consecutive `k`s are adjacent in
//! memory, so a whole `(row, panel)` pass is one sequential sweep over
//! `din * panel_w` elements. The transform is pure layout: every weight
//! element keeps its value, and the packed kernels in
//! [`super::nm`] / [`super::dense`] / [`super::int8`] add the exact same
//! contributions in the exact same ascending-`k` order as the row-major
//! tiled kernels, so outputs stay **bitwise identical** to
//! [`super::reference`] (pinned by `tests/kernel_parity.rs`).
//!
//! Packing costs one pass over the matrix and one `din * dout` copy; it
//! is done **once per weight at [`Engine::bind`] time** by the prep
//! cache ([`crate::runtime`]'s native backend), never in a hot path.
//!
//! [`Engine::bind`]: crate::runtime::Engine::bind

use super::clamp_tile;

/// A `[din, dout]` matrix stored as contiguous tile panels of
/// `panel_w` output columns (the last panel ragged when `panel_w` does
/// not divide `dout`). Generic over the element type so the f32 and
/// int8 (W8A8) weight paths share one layout.
#[derive(Debug, Clone)]
pub struct PackedPanels<T> {
    /// contraction width (input channels)
    pub din: usize,
    /// total output columns across all panels
    pub dout: usize,
    /// full-panel width (clamped to `1..=`[`super::MAX_DOUT_TILE`])
    pub panel_w: usize,
    /// panel-major storage: panel `p` holds `din * width(p)` elements
    data: Vec<T>,
}

impl<T: Copy> PackedPanels<T> {
    /// Pack a row-major `[din, dout]` matrix into panels of `panel_w`
    /// columns (clamped to the supported tile range).
    ///
    /// # Panics
    /// When `w.len() != din * dout`.
    pub fn pack(w: &[T], din: usize, dout: usize, panel_w: usize) -> Self {
        assert_eq!(w.len(), din * dout, "pack: weight shape");
        let panel_w = clamp_tile(panel_w);
        let mut data = Vec::with_capacity(din * dout);
        let mut c0 = 0;
        while c0 < dout {
            let tw = panel_w.min(dout - c0);
            for k in 0..din {
                let start = k * dout + c0;
                data.extend_from_slice(&w[start..start + tw]);
            }
            c0 += tw;
        }
        PackedPanels { din, dout, panel_w, data }
    }

    /// Number of panels (`ceil(dout / panel_w)`).
    pub fn n_panels(&self) -> usize {
        self.dout.div_ceil(self.panel_w)
    }

    /// Panel `p` as `(first column, width, din-by-width slice)`. Every
    /// column stores exactly `din` elements, so panel `p`'s offset is
    /// simply `first_column * din`.
    pub fn panel(&self, p: usize) -> (usize, usize, &[T]) {
        let c0 = p * self.panel_w;
        debug_assert!(c0 < self.dout, "panel index out of range");
        let tw = self.panel_w.min(self.dout - c0);
        let off = c0 * self.din;
        (c0, tw, &self.data[off..off + self.din * tw])
    }

    /// Storage footprint in bytes (the packed copy only).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }

    /// Reconstruct the row-major `[din, dout]` matrix (tests /
    /// verification — the layout transform must be lossless).
    pub fn unpack(&self) -> Vec<T>
    where
        T: Default,
    {
        let mut out = vec![T::default(); self.din * self.dout];
        for p in 0..self.n_panels() {
            let (c0, tw, panel) = self.panel(p);
            for k in 0..self.din {
                out[k * self.dout + c0..k * self.dout + c0 + tw]
                    .copy_from_slice(&panel[k * tw..(k + 1) * tw]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_roundtrips_row_major() {
        let mut rng = Rng::new(21);
        for &(din, dout) in &[(3usize, 5usize), (16, 37), (8, 8), (2, 1)] {
            let w: Vec<f32> =
                (0..din * dout).map(|_| rng.normal() as f32).collect();
            for &pw in &[1usize, 4, 8, 16, 64] {
                let p = PackedPanels::pack(&w, din, dout, pw);
                assert_eq!(p.unpack(), w, "din={din} dout={dout} pw={pw}");
                assert_eq!(p.bytes(), din * dout * 4);
            }
        }
    }

    #[test]
    fn panel_geometry_covers_every_column_once() {
        let (din, dout, pw) = (4usize, 21usize, 8usize);
        let w: Vec<f32> = (0..din * dout).map(|i| i as f32).collect();
        let p = PackedPanels::pack(&w, din, dout, pw);
        assert_eq!(p.n_panels(), 3);
        let mut covered = 0usize;
        for i in 0..p.n_panels() {
            let (c0, tw, panel) = p.panel(i);
            assert_eq!(c0, i * pw);
            assert_eq!(panel.len(), din * tw);
            // element (k, c0 + j) must be w[k*dout + c0 + j]
            for k in 0..din {
                for j in 0..tw {
                    assert_eq!(panel[k * tw + j], w[k * dout + c0 + j]);
                }
            }
            covered += tw;
        }
        assert_eq!(covered, dout);
    }

    #[test]
    fn int8_packing_shares_the_layout() {
        let (din, dout) = (4usize, 13usize);
        let w: Vec<i8> =
            (0..din * dout).map(|i| (i % 251) as i8).collect();
        let p = PackedPanels::pack(&w, din, dout, 8);
        assert_eq!(p.unpack(), w);
        assert_eq!(p.bytes(), din * dout);
    }

    #[test]
    #[should_panic(expected = "pack: weight shape")]
    fn pack_rejects_bad_shape() {
        PackedPanels::pack(&[0.0f32; 7], 2, 4, 8);
    }
}
