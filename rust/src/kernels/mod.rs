//! Register-tiled, cache-blocked CPU microkernels — the compute core
//! every hot matmul in the crate dispatches to.
//!
//! # Why a kernel layer
//!
//! The paper's speedup claim lives or dies on the N:M SpMM actually
//! beating the dense matmul it replaces. The original kernels were
//! naive axpy loops: for every input channel they re-streamed the full
//! `dout`-wide accumulator row plus one full weight row, so at
//! realistic `dout` the accumulator fell out of L1 on every pass and
//! the sparse kernel's FLOP savings were eaten by memory traffic. The
//! tiled kernels here iterate a fixed `dout`-tile of accumulators kept
//! in registers over the row's contraction axis instead:
//!
//! ```text
//! for each token row r:
//!   for each dout-tile [c0, c0+W):
//!     acc[0..W] = 0                       // W registers
//!     for each k (nonzero / channel of row r, ascending):
//!       acc[j] += x[r,k] * w[k, c0+j]    // one W-wide FMA row
//!     out[r, c0..c0+W] = acc
//! ```
//!
//! The accumulator tile never leaves registers, the weight tile is
//! streamed exactly once per (row, tile), and the compressed N:M row
//! (`din·n/m` value/index pairs — constant per row by the exact-N:M
//! contract, so the walk is branch-free fixed-stride) stays L1-resident
//! while it is re-streamed once per tile.
//!
//! # Bitwise parity with the reference kernels
//!
//! For every output element `out[r, c]`, the tiled kernels add the
//! same contributions `x[r,k]·w[k,c]` in the same ascending-`k` order,
//! starting from `+0.0`, one `f32` add at a time — exactly the
//! per-element reduction chain of the naive loops (which interleave
//! different `c`s between adds, but each element's own chain is
//! unchanged). Rust never contracts `a*b + c` into an FMA on its own,
//! so the tiled outputs are **bitwise identical** to the retained
//! [`reference`] kernels for every tile width, and tile width is a pure
//! performance knob. `tests/kernel_parity.rs` pins this property across
//! ratios, shapes, tile widths, row-block heights and pool widths.
//!
//! The int8 kernel accumulates in `i32` (exact, associative), then
//! dequantizes each element as `(acc as f32 * x_scale) * w_scale[c]` —
//! the same expression, in the same association order, as the
//! reference, with per-token `x_scale` support fused at dequant.
//!
//! # Panel-packed weights
//!
//! The kernels above still stream a row-major weight with a
//! `dout`-wide stride between contraction steps. The [`pack`] module
//! stores the weight as contiguous **tile panels** instead
//! ([`pack::PackedPanels`], built once per weight at bind time by the
//! native engine's prep cache), and each kernel family has a
//! `*_packed` variant whose inner loop streams the panel unit-stride.
//! The panel transform is pure layout: the packed kernels add the same
//! contributions in the same ascending-`k` order, so they remain
//! bitwise identical to [`reference`] (see the [`pack`] docs for the
//! layout and the argument).
//!
//! # Explicit SIMD with runtime dispatch
//!
//! The [`simd`] module (behind the `simd` cargo feature) re-implements
//! the three `*_packed` families with explicit AVX-512 / AVX2 / NEON
//! inner loops, resolved **once** into a [`simd::Dispatch`] vtable of
//! function pointers at `NativeEngine::bind` and threaded through the
//! execution options — the hot paths never probe the CPU. The vector
//! strategy (a register holds adjacent output columns; `k` stays a
//! scalar-ordered loop; separate multiply + add, never FMA) preserves
//! every element's reduction chain, so all levels remain bitwise
//! identical to [`reference`] (the `simd_` family in
//! `tests/kernel_parity.rs`, run as the `simd-parity` CI gate).
//!
//! # Tuning
//!
//! [`DEFAULT_DOUT_TILE`] (8) fits comfortably in two SSE / one AVX2
//! register set with room for the broadcast multiplier; widths 4, 8,
//! 16 and 32 get const-unrolled fast paths, anything else (and every
//! ragged tail tile) takes the runtime-width path. The knob rides on
//! [`crate::sparsity::plan::SparsityPlan::tiles`] and is clamped to
//! `1..=`[`MAX_DOUT_TILE`]; since the bind-time preparation layer it is
//! planned **per module** from the model geometry
//! ([`crate::sparsity::plan::TileTable`]) and stamped into each packed
//! weight.

pub mod dense;
pub mod int8;
pub mod nm;
pub mod pack;
pub mod reference;
pub mod simd;

/// Default accumulator-tile width (output columns per register tile).
pub const DEFAULT_DOUT_TILE: usize = 8;

/// Ceiling for the tile-width knob: the runtime-width fallback keeps
/// its accumulators in one stack array of this size.
pub const MAX_DOUT_TILE: usize = 64;

/// Clamp a user-supplied tile width into the supported range.
#[inline]
pub fn clamp_tile(dout_tile: usize) -> usize {
    dout_tile.clamp(1, MAX_DOUT_TILE)
}
