//! x86-64 vector microkernels: AVX2 and AVX-512F lanes over the panel
//! layout. See the [module docs](super) for the dispatch design and
//! the bitwise argument; the one rule enforced throughout this file is
//! **separate vector multiply then vector add per k-step** (`mul_ps`
//! + `add_ps`, never `fmadd`), because an FMA would skip the
//! intermediate rounding every scalar chain performs.
//!
//! Each kernel mirrors its scalar `*_packed` twin exactly: rows outer,
//! panels inner, and per `(row, panel)` a bank of lane accumulators
//! covering `tw / LANES` vector chunks plus scalar-tail accumulators
//! for the ragged remainder (`tw % LANES` columns). Every load/store
//! is unaligned (`loadu`/`storeu`) and stays inside the panel slice /
//! output row by the chunk arithmetic.

use super::super::pack::PackedPanels;
use super::super::MAX_DOUT_TILE;
use std::arch::x86_64::*;

/// AVX2 present (FMA probed alongside to tag the CPU tier; the
/// kernels never emit FMA — the bitwise contract forbids it).
pub(super) fn avx2_available() -> bool {
    std::is_x86_feature_detected!("avx2")
        && std::is_x86_feature_detected!("fma")
}

/// AVX-512 foundation present (all intrinsics used here are AVX512F).
pub(super) fn avx512_available() -> bool {
    std::is_x86_feature_detected!("avx512f")
}

// ---------------------------------------------------------------- AVX2

const L8: usize = 8; // f32 / i32 lanes per 256-bit register
const V8: usize = MAX_DOUT_TILE / L8; // accumulator bank size

/// Panel-packed dense matmul, AVX2 lanes. Signature and panics match
/// [`dense_tiled_packed`](crate::kernels::dense::dense_tiled_packed).
pub(super) fn dense_avx2(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.din, din, "weight contraction width");
    assert_eq!(out.len(), t * w.dout, "output shape");
    // SAFETY: `Dispatch::force` hands this pointer out only after
    // `avx2_available()` returned true on this CPU.
    unsafe { dense_avx2_impl(x, t, din, w, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn dense_avx2_impl(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L8;
            let tail0 = nv * L8;
            let pp = panel.as_ptr();
            let mut vacc = [_mm256_setzero_ps(); V8];
            let mut sacc = [0.0f32; L8 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vs = _mm256_set1_ps(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = _mm256_loadu_ps(wrow.add(j * L8));
                    *a = _mm256_add_ps(*a, _mm256_mul_ps(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                _mm256_storeu_ps(op.add(j * L8), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed N:M SpMM, AVX2 lanes. Signature and panics match
/// [`spmm_nm_tiled_packed`](crate::kernels::nm::spmm_nm_tiled_packed);
/// keeps the `v == 0.0` skip branch.
pub(super) fn spmm_avx2(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    assert_eq!(out.len(), rows * w.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected AVX2.
    unsafe { spmm_avx2_impl(values, index, rows, per_row, w, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn spmm_avx2_impl(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..rows {
        let vals = &values[r * per_row..(r + 1) * per_row];
        let idx = &index[r * per_row..(r + 1) * per_row];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L8;
            let tail0 = nv * L8;
            let pp = panel.as_ptr();
            let mut vacc = [_mm256_setzero_ps(); V8];
            let mut sacc = [0.0f32; L8 - 1];
            for (&v, &ci) in vals.iter().zip(idx.iter()) {
                if v == 0.0 {
                    continue;
                }
                let wrow = pp.add(ci as usize * tw);
                let vs = _mm256_set1_ps(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = _mm256_loadu_ps(wrow.add(j * L8));
                    *a = _mm256_add_ps(*a, _mm256_mul_ps(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                _mm256_storeu_ps(op.add(j * L8), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed per-token W8A8 matmul, AVX2 lanes: widening
/// `i8 → i32` lane accumulation (exact), vector dequant in the scalar
/// association order. Signature and panics match
/// [`w8a8_tiled_per_token_packed`](crate::kernels::int8::w8a8_tiled_per_token_packed).
pub(super) fn w8a8_avx2(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    assert_eq!(xq.len(), t * din, "activation shape");
    assert_eq!(wq.din, din, "weight contraction width");
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    assert_eq!(w_scales.len(), wq.dout, "one weight scale per column");
    assert_eq!(out.len(), t * wq.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected AVX2.
    unsafe { w8a8_avx2_impl(xq, t, din, wq, x_scales, w_scales, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn w8a8_avx2_impl(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    let dout = wq.dout;
    for r in 0..t {
        let xrow = &xq[r * din..(r + 1) * din];
        let xs = x_scales[r];
        for p in 0..wq.n_panels() {
            let (c0, tw, panel) = wq.panel(p);
            let nv = tw / L8;
            let tail0 = nv * L8;
            let pp = panel.as_ptr();
            let mut vacc = [_mm256_setzero_si256(); V8];
            let mut sacc = [0i32; L8 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vv = _mm256_set1_epi32(v as i32);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    // 8 i8 weights, sign-extended to i32 lanes
                    let wb = _mm_loadl_epi64(
                        wrow.add(j * L8) as *const __m128i
                    );
                    let wi = _mm256_cvtepi8_epi32(wb);
                    *a = _mm256_add_epi32(
                        *a,
                        _mm256_mullo_epi32(vv, wi),
                    );
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v as i32 * *wrow.add(tail0 + i) as i32;
                }
            }
            let ws = w_scales.as_ptr().add(c0);
            let op = out.as_mut_ptr().add(r * dout + c0);
            let vxs = _mm256_set1_ps(xs);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                // (cvt(acc) * x_scale) * w_scale — scalar association
                let f = _mm256_cvtepi32_ps(*a);
                let f = _mm256_mul_ps(f, vxs);
                let f = _mm256_mul_ps(f, _mm256_loadu_ps(ws.add(j * L8)));
                _mm256_storeu_ps(op.add(j * L8), f);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) =
                    *a as f32 * xs * *ws.add(tail0 + i);
            }
        }
    }
}

// ------------------------------------------------------------- AVX-512

const L16: usize = 16; // f32 / i32 lanes per 512-bit register
const V16: usize = MAX_DOUT_TILE / L16; // accumulator bank size

/// Panel-packed dense matmul, AVX-512F lanes (contract as
/// [`dense_avx2`]).
pub(super) fn dense_avx512(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.din, din, "weight contraction width");
    assert_eq!(out.len(), t * w.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected
    // AVX-512F.
    unsafe { dense_avx512_impl(x, t, din, w, out) }
}

#[target_feature(enable = "avx512f")]
unsafe fn dense_avx512_impl(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L16;
            let tail0 = nv * L16;
            let pp = panel.as_ptr();
            let mut vacc = [_mm512_setzero_ps(); V16];
            let mut sacc = [0.0f32; L16 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vs = _mm512_set1_ps(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = _mm512_loadu_ps(wrow.add(j * L16));
                    *a = _mm512_add_ps(*a, _mm512_mul_ps(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                _mm512_storeu_ps(op.add(j * L16), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed N:M SpMM, AVX-512F lanes (contract as [`spmm_avx2`]).
pub(super) fn spmm_avx512(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    assert_eq!(out.len(), rows * w.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected
    // AVX-512F.
    unsafe { spmm_avx512_impl(values, index, rows, per_row, w, out) }
}

#[target_feature(enable = "avx512f")]
unsafe fn spmm_avx512_impl(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..rows {
        let vals = &values[r * per_row..(r + 1) * per_row];
        let idx = &index[r * per_row..(r + 1) * per_row];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L16;
            let tail0 = nv * L16;
            let pp = panel.as_ptr();
            let mut vacc = [_mm512_setzero_ps(); V16];
            let mut sacc = [0.0f32; L16 - 1];
            for (&v, &ci) in vals.iter().zip(idx.iter()) {
                if v == 0.0 {
                    continue;
                }
                let wrow = pp.add(ci as usize * tw);
                let vs = _mm512_set1_ps(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = _mm512_loadu_ps(wrow.add(j * L16));
                    *a = _mm512_add_ps(*a, _mm512_mul_ps(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                _mm512_storeu_ps(op.add(j * L16), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed per-token W8A8 matmul, AVX-512F lanes (contract as
/// [`w8a8_avx2`]).
pub(super) fn w8a8_avx512(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    assert_eq!(xq.len(), t * din, "activation shape");
    assert_eq!(wq.din, din, "weight contraction width");
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    assert_eq!(w_scales.len(), wq.dout, "one weight scale per column");
    assert_eq!(out.len(), t * wq.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected
    // AVX-512F.
    unsafe { w8a8_avx512_impl(xq, t, din, wq, x_scales, w_scales, out) }
}

#[target_feature(enable = "avx512f")]
unsafe fn w8a8_avx512_impl(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    let dout = wq.dout;
    for r in 0..t {
        let xrow = &xq[r * din..(r + 1) * din];
        let xs = x_scales[r];
        for p in 0..wq.n_panels() {
            let (c0, tw, panel) = wq.panel(p);
            let nv = tw / L16;
            let tail0 = nv * L16;
            let pp = panel.as_ptr();
            let mut vacc = [_mm512_setzero_si512(); V16];
            let mut sacc = [0i32; L16 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vv = _mm512_set1_epi32(v as i32);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    // 16 i8 weights, sign-extended to i32 lanes
                    let wb = _mm_loadu_si128(
                        wrow.add(j * L16) as *const __m128i
                    );
                    let wi = _mm512_cvtepi8_epi32(wb);
                    *a = _mm512_add_epi32(
                        *a,
                        _mm512_mullo_epi32(vv, wi),
                    );
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v as i32 * *wrow.add(tail0 + i) as i32;
                }
            }
            let ws = w_scales.as_ptr().add(c0);
            let op = out.as_mut_ptr().add(r * dout + c0);
            let vxs = _mm512_set1_ps(xs);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                let f = _mm512_cvtepi32_ps(*a);
                let f = _mm512_mul_ps(f, vxs);
                let f =
                    _mm512_mul_ps(f, _mm512_loadu_ps(ws.add(j * L16)));
                _mm512_storeu_ps(op.add(j * L16), f);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) =
                    *a as f32 * xs * *ws.add(tail0 + i);
            }
        }
    }
}
