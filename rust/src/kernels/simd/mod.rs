//! Explicit-SIMD microkernels with one-time runtime CPU dispatch.
//!
//! # The dispatch design
//!
//! The register-tiled `*_packed` kernels trust LLVM to vectorize; this
//! module makes the vector code explicit — AVX-512 / AVX2+FMA / NEON
//! inner loops over the same [`PackedPanels`] layout — behind the
//! `simd` cargo feature. CPU capability is probed **once** (a cached
//! [`Level`] detection) and resolved into a [`Dispatch`] vtable of
//! plain function pointers at `NativeEngine::bind`; the hot paths call
//! through the vtable and never probe per call. This module is the
//! only place a CPU-feature probe may appear (a CI grep guard rejects
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`
//! anywhere else).
//!
//! # Why SIMD stays bitwise-identical
//!
//! The vector strategy is **vectorize across outputs, not along k**: a
//! SIMD register holds adjacent output *columns* of a panel (unit
//! stride, thanks to the panel layout), and the contraction axis `k`
//! remains a scalar-ordered loop. Each output element therefore keeps
//! exactly the per-element reduction chain of the scalar kernels —
//! same contributions, same ascending-`k` order, one f32 add per step
//! — so f32 SIMD == tiled == reference **bitwise**. Two details make
//! this airtight:
//!
//! * every k-step is a separate vector multiply then vector add
//!   (`mul_ps` + `add_ps`, never `fmadd`): an FMA would skip the
//!   intermediate rounding the scalar chain performs;
//! * the N:M kernels keep the `v == 0.0` skip branch (skipping a
//!   stored zero is not a no-op for `-0.0` accumulators).
//!
//! A panel wider than the vector is processed as `tw / lanes` vector
//! chunks plus a scalar tail — columns are independent, so mixing
//! vector and scalar columns cannot change any element's chain. The
//! int8 kernels widen each `i8` pair into `i32` lanes and accumulate
//! in `i32` (exact, associative — lane order is irrelevant), then
//! dequantize as `(cvt(acc) * x_scale) * w_scale[c]`, the same
//! association order as scalar; hardware `i32 → f32` conversion
//! rounds to nearest even exactly like `as f32`.
//!
//! `tests/kernel_parity.rs` pins every *available* level against the
//! scalar kernels across the full shape matrix (the `simd_` family,
//! run as the `simd-parity` CI gate).
//!
//! [`PackedPanels`]: super::pack::PackedPanels

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86;

use super::pack::PackedPanels;
use super::{dense, int8, nm};
use std::sync::OnceLock;

/// A resolved CPU-dispatch level. `Scalar` is the register-tiled
/// fallback and always available; the vector levels exist only when
/// the `simd` feature is on *and* the running CPU reports the ISA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Level {
    /// Scalar register-tiled kernels (the `*_packed` baseline).
    Scalar,
    /// AVX2 (detected together with FMA — FMA is never *used*, the
    /// bitwise contract forbids contraction; it tags the CPU tier).
    Avx2,
    /// AVX-512F: 16 f32 / i32 lanes per register.
    Avx512,
    /// aarch64 NEON: 4 f32 / i32 lanes per register.
    Neon,
}

impl Level {
    /// f32 lanes per vector register at this level — the unit the tile
    /// planner rounds panel widths to so full panels have no scalar
    /// tail.
    pub fn lanes_f32(self) -> usize {
        match self {
            Level::Scalar => 1,
            Level::Neon => 4,
            Level::Avx2 => 8,
            Level::Avx512 => 16,
        }
    }

    /// Stable lowercase name (bench/report labels).
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
            Level::Avx512 => "avx512",
            Level::Neon => "neon",
        }
    }
}

/// Kernel vtable for one dispatch level: the three packed kernel
/// families behind plain function pointers with the exact signatures
/// of the scalar `*_packed` kernels. Resolved once (at bind) and
/// threaded through `ExecOpts` — calling through it never probes the
/// CPU.
#[derive(Debug, Clone, Copy)]
pub struct Dispatch {
    /// The level this vtable was resolved for.
    pub level: Level,
    /// Panel-packed N:M SpMM (see [`nm::spmm_nm_tiled_packed`]).
    pub spmm:
        fn(&[f32], &[u32], usize, usize, &PackedPanels<f32>, &mut [f32]),
    /// Panel-packed dense matmul (see [`dense::dense_tiled_packed`]).
    pub dense: fn(&[f32], usize, usize, &PackedPanels<f32>, &mut [f32]),
    /// Panel-packed per-token W8A8 matmul (see
    /// [`int8::w8a8_tiled_per_token_packed`]).
    pub w8a8: fn(
        &[i8],
        usize,
        usize,
        &PackedPanels<i8>,
        &[f32],
        &[f32],
        &mut [f32],
    ),
}

impl Default for Dispatch {
    fn default() -> Self {
        Dispatch::scalar()
    }
}

impl PartialEq for Dispatch {
    fn eq(&self, other: &Self) -> bool {
        self.level == other.level
    }
}

impl Eq for Dispatch {}

impl Dispatch {
    /// The scalar vtable: the register-tiled `*_packed` kernels,
    /// available on every build and every CPU.
    pub fn scalar() -> Dispatch {
        Dispatch {
            level: Level::Scalar,
            spmm: nm::spmm_nm_tiled_packed,
            dense: dense::dense_tiled_packed,
            w8a8: int8::w8a8_tiled_per_token_packed,
        }
    }

    /// The vtable for the best level this CPU supports. Detection runs
    /// once per process (cached); without the `simd` feature this is
    /// always [`Dispatch::scalar`].
    pub fn auto() -> Dispatch {
        static BEST: OnceLock<Level> = OnceLock::new();
        let level = *BEST.get_or_init(detect_level);
        Dispatch::force(level).expect("detected level must resolve")
    }

    /// The vtable for a specific level, or `None` when that level is
    /// not available (feature off, wrong arch, or the CPU lacks the
    /// ISA) — the test/tuning override behind
    /// `NativeEngine::with_dispatch_level`. `Scalar` always resolves.
    pub fn force(level: Level) -> Option<Dispatch> {
        match level {
            Level::Scalar => Some(Dispatch::scalar()),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Level::Avx2 if x86::avx2_available() => Some(Dispatch {
                level,
                spmm: x86::spmm_avx2,
                dense: x86::dense_avx2,
                w8a8: x86::w8a8_avx2,
            }),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Level::Avx512 if x86::avx512_available() => Some(Dispatch {
                level,
                spmm: x86::spmm_avx512,
                dense: x86::dense_avx512,
                w8a8: x86::w8a8_avx512,
            }),
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            Level::Neon if neon::neon_available() => Some(Dispatch {
                level,
                spmm: neon::spmm_neon,
                dense: neon::dense_neon,
                w8a8: neon::w8a8_neon,
            }),
            _ => None,
        }
    }

    /// Every level that resolves on this build + CPU, best-first
    /// (`Scalar` is always last). Parity tests sweep this.
    pub fn available_levels() -> Vec<Level> {
        [Level::Avx512, Level::Avx2, Level::Neon, Level::Scalar]
            .into_iter()
            .filter(|&l| Dispatch::force(l).is_some())
            .collect()
    }
}

/// Probe the CPU for the best supported level. The only runtime
/// feature detection in the crate; called once through the
/// [`Dispatch::auto`] cache.
fn detect_level() -> Level {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if x86::avx512_available() {
            return Level::Avx512;
        }
        if x86::avx2_available() {
            return Level::Avx2;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if neon::neon_available() {
            return Level::Neon;
        }
    }
    Level::Scalar
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_always_resolves_and_auto_is_cached() {
        assert_eq!(Dispatch::force(Level::Scalar).unwrap().level, Level::Scalar);
        let a = Dispatch::auto();
        let b = Dispatch::auto();
        assert_eq!(a.level, b.level);
        assert!(Dispatch::available_levels().contains(&a.level));
        assert_eq!(Dispatch::available_levels().last(), Some(&Level::Scalar));
    }

    #[test]
    fn every_available_level_matches_scalar_on_a_ragged_shape() {
        // the full matrix lives in tests/kernel_parity.rs (simd_
        // family); this is the in-crate smoke over one awkward shape
        let mut rng = Rng::new(29);
        let (t, din, dout) = (5usize, 24usize, 37usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        for pw in [5usize, 8, 16, 32] {
            let packed = PackedPanels::pack(&w, din, dout, pw);
            let mut golden = vec![0.0f32; t * dout];
            (Dispatch::scalar().dense)(&x, t, din, &packed, &mut golden);
            for level in Dispatch::available_levels() {
                let d = Dispatch::force(level).unwrap();
                let mut out = vec![0.0f32; t * dout];
                (d.dense)(&x, t, din, &packed, &mut out);
                assert_eq!(out, golden, "level {level:?} pw {pw}");
            }
        }
    }
}
