//! aarch64 NEON vector microkernels over the panel layout. Same
//! contract as the x86 file: vectorize across output columns, keep the
//! scalar ascending-`k` chain per element, and use separate vector
//! multiply then vector add for f32 (`vmulq_f32` + `vaddq_f32`, never
//! `vfmaq_f32` — fused would skip the intermediate rounding). The f32
//! kernels chunk panels by 4 lanes; the int8 kernel widens 8 weights
//! at a time into two `i32x4` accumulators (`vmlaq_s32` is exact
//! integer multiply-add, so fusing is fine there).

use super::super::pack::PackedPanels;
use super::super::MAX_DOUT_TILE;
use std::arch::aarch64::*;

/// NEON present (architecturally mandatory on aarch64; probed anyway
/// so every vector level flows through the same detection story).
pub(super) fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

const L4: usize = 4; // f32 / i32 lanes per 128-bit register
const V4: usize = MAX_DOUT_TILE / L4; // f32 accumulator bank size
const LI8: usize = 8; // int8 columns widened per load
const VI8: usize = 2 * (MAX_DOUT_TILE / LI8); // paired i32x4 bank

/// Panel-packed dense matmul, NEON lanes. Signature and panics match
/// [`dense_tiled_packed`](crate::kernels::dense::dense_tiled_packed).
pub(super) fn dense_neon(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.din, din, "weight contraction width");
    assert_eq!(out.len(), t * w.dout, "output shape");
    // SAFETY: `Dispatch::force` hands this pointer out only after
    // `neon_available()` returned true on this CPU.
    unsafe { dense_neon_impl(x, t, din, w, out) }
}

#[target_feature(enable = "neon")]
unsafe fn dense_neon_impl(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L4;
            let tail0 = nv * L4;
            let pp = panel.as_ptr();
            let mut vacc = [vdupq_n_f32(0.0); V4];
            let mut sacc = [0.0f32; L4 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vs = vdupq_n_f32(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = vld1q_f32(wrow.add(j * L4));
                    *a = vaddq_f32(*a, vmulq_f32(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                vst1q_f32(op.add(j * L4), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed N:M SpMM, NEON lanes. Signature and panics match
/// [`spmm_nm_tiled_packed`](crate::kernels::nm::spmm_nm_tiled_packed);
/// keeps the `v == 0.0` skip branch.
pub(super) fn spmm_neon(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    assert_eq!(out.len(), rows * w.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected NEON.
    unsafe { spmm_neon_impl(values, index, rows, per_row, w, out) }
}

#[target_feature(enable = "neon")]
unsafe fn spmm_neon_impl(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    let dout = w.dout;
    for r in 0..rows {
        let vals = &values[r * per_row..(r + 1) * per_row];
        let idx = &index[r * per_row..(r + 1) * per_row];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let nv = tw / L4;
            let tail0 = nv * L4;
            let pp = panel.as_ptr();
            let mut vacc = [vdupq_n_f32(0.0); V4];
            let mut sacc = [0.0f32; L4 - 1];
            for (&v, &ci) in vals.iter().zip(idx.iter()) {
                if v == 0.0 {
                    continue;
                }
                let wrow = pp.add(ci as usize * tw);
                let vs = vdupq_n_f32(v);
                for (j, a) in vacc.iter_mut().enumerate().take(nv) {
                    let wv = vld1q_f32(wrow.add(j * L4));
                    *a = vaddq_f32(*a, vmulq_f32(vs, wv));
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v * *wrow.add(tail0 + i);
                }
            }
            let op = out.as_mut_ptr().add(r * dout + c0);
            for (j, a) in vacc.iter().enumerate().take(nv) {
                vst1q_f32(op.add(j * L4), *a);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) = *a;
            }
        }
    }
}

/// Panel-packed per-token W8A8 matmul, NEON lanes: widening `i8 → i32`
/// accumulation (exact), vector dequant in the scalar association
/// order. Signature and panics match
/// [`w8a8_tiled_per_token_packed`](crate::kernels::int8::w8a8_tiled_per_token_packed).
pub(super) fn w8a8_neon(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    assert_eq!(xq.len(), t * din, "activation shape");
    assert_eq!(wq.din, din, "weight contraction width");
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    assert_eq!(w_scales.len(), wq.dout, "one weight scale per column");
    assert_eq!(out.len(), t * wq.dout, "output shape");
    // SAFETY: handed out by `Dispatch::force` only under detected NEON.
    unsafe { w8a8_neon_impl(xq, t, din, wq, x_scales, w_scales, out) }
}

#[target_feature(enable = "neon")]
unsafe fn w8a8_neon_impl(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    let dout = wq.dout;
    for r in 0..t {
        let xrow = &xq[r * din..(r + 1) * din];
        let xs = x_scales[r];
        for p in 0..wq.n_panels() {
            let (c0, tw, panel) = wq.panel(p);
            let nv = tw / LI8;
            let tail0 = nv * LI8;
            let pp = panel.as_ptr();
            let mut vacc = [vdupq_n_s32(0); VI8];
            let mut sacc = [0i32; LI8 - 1];
            for (k, &v) in xrow.iter().enumerate() {
                let wrow = pp.add(k * tw);
                let vv = vdupq_n_s32(v as i32);
                for j in 0..nv {
                    // 8 i8 weights -> i16x8 -> two i32x4 lanes
                    let wb = vld1_s8(wrow.add(j * LI8));
                    let w16 = vmovl_s8(wb);
                    let lo = vmovl_s16(vget_low_s16(w16));
                    let hi = vmovl_s16(vget_high_s16(w16));
                    vacc[2 * j] = vmlaq_s32(vacc[2 * j], lo, vv);
                    vacc[2 * j + 1] =
                        vmlaq_s32(vacc[2 * j + 1], hi, vv);
                }
                for (i, a) in
                    sacc.iter_mut().enumerate().take(tw - tail0)
                {
                    *a += v as i32 * *wrow.add(tail0 + i) as i32;
                }
            }
            let ws = w_scales.as_ptr().add(c0);
            let op = out.as_mut_ptr().add(r * dout + c0);
            let vxs = vdupq_n_f32(xs);
            for h in 0..2 * nv {
                // (cvt(acc) * x_scale) * w_scale — scalar association
                let f = vcvtq_f32_s32(vacc[h]);
                let f = vmulq_f32(f, vxs);
                let f = vmulq_f32(f, vld1q_f32(ws.add(h * L4)));
                vst1q_f32(op.add(h * L4), f);
            }
            for (i, a) in sacc.iter().enumerate().take(tw - tail0) {
                *op.add(tail0 + i) =
                    *a as f32 * xs * *ws.add(tail0 + i);
            }
        }
    }
}
