//! Register-tiled W8A8 matmul: int8 operands, `i32` accumulation, and
//! activation scales fused at dequant — per-tensor or **per-token**.
//!
//! Integer accumulation is exact, so the tile order cannot change the
//! accumulator; bitwise parity with
//! [`reference::w8a8_per_token`](super::reference::w8a8_per_token)
//! additionally requires the dequant expression to match — both
//! kernels compute `(acc as f32 * x_scale) * w_scale[c]` in that
//! association order. Per-token scales make a token's quantized output
//! depend only on its own row (not its batchmates), which is what
//! turns packed-vs-sequential sq prefill parity from tolerance-based
//! into bitwise (`tests/kernel_parity.rs`).

use super::pack::PackedPanels;
use super::{clamp_tile, MAX_DOUT_TILE};

/// One `(row, tile)` microkernel at const width `W`: `W` i32
/// accumulators in registers, dequantized on store.
#[inline(always)]
fn row_tile<const W: usize>(
    xrow: &[i8],
    wq: &[i8],
    dout: usize,
    c0: usize,
    x_scale: f32,
    w_scales: &[f32],
    out: &mut [f32],
) {
    let mut acc = [0i32; W];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow: &[i8; W] =
            wq[start..start + W].try_into().expect("tile width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v as i32 * wv as i32;
        }
    }
    let ws = &w_scales[c0..c0 + W];
    for ((o, &a), &s) in out[..W].iter_mut().zip(acc.iter()).zip(ws) {
        *o = a as f32 * x_scale * s;
    }
}

/// Runtime-width `(row, tile)` microkernel for ragged tails and
/// non-specialized tile widths.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn row_tile_dyn(
    xrow: &[i8],
    wq: &[i8],
    dout: usize,
    c0: usize,
    tw: usize,
    x_scale: f32,
    w_scales: &[f32],
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0i32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow = &wq[start..start + tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v as i32 * wv as i32;
        }
    }
    let ws = &w_scales[c0..c0 + tw];
    for ((o, &a), &s) in out[..tw].iter_mut().zip(acc.iter()).zip(ws) {
        *o = a as f32 * x_scale * s;
    }
}

/// Tiled W8A8 matmul with **per-token** activation scales:
/// `xq [t, din] @ wq [din, dout]` with `i32` accumulation, dequantized
/// as `(acc as f32 * x_scales[r]) * w_scales[c]` into `out`
/// (`[t, dout]`, fully overwritten).
#[allow(clippy::too_many_arguments)]
pub fn w8a8_tiled_per_token(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    dout_tile: usize,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    assert_eq!(xq.len(), t * din, "activation shape");
    assert_eq!(wq.len(), din * dout, "weight shape");
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    assert_eq!(w_scales.len(), dout, "one weight scale per column");
    assert_eq!(out.len(), t * dout, "output shape");
    let tile = clamp_tile(dout_tile);
    for r in 0..t {
        let xrow = &xq[r * din..(r + 1) * din];
        let xs = x_scales[r];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut c0 = 0;
        while c0 < dout {
            let tw = tile.min(dout - c0);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_tile::<4>(xrow, wq, dout, c0, xs, w_scales, ot),
                8 => row_tile::<8>(xrow, wq, dout, c0, xs, w_scales, ot),
                16 => row_tile::<16>(xrow, wq, dout, c0, xs, w_scales, ot),
                32 => row_tile::<32>(xrow, wq, dout, c0, xs, w_scales, ot),
                _ => row_tile_dyn(
                    xrow, wq, dout, c0, tw, xs, w_scales, ot,
                ),
            }
            c0 += tw;
        }
    }
}

/// Tiled W8A8 matmul with one **per-tensor** activation scale — the
/// per-token kernel with the scale broadcast to every row.
#[allow(clippy::too_many_arguments)]
pub fn w8a8_tiled(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    dout_tile: usize,
    x_scale: f32,
    w_scales: &[f32],
    out: &mut [f32],
) {
    let scales = vec![x_scale; t];
    w8a8_tiled_per_token(
        xq, t, din, wq, dout, dout_tile, &scales, w_scales, out,
    );
}

/// One `(row, panel)` microkernel at const width `W` over a packed
/// int8 panel: `W` i32 accumulators, sequential panel sweep,
/// dequantized on store with the panel's slice of the column scales.
#[inline(always)]
fn row_panel<const W: usize>(
    xrow: &[i8],
    panel: &[i8],
    x_scale: f32,
    w_scales: &[f32],
    out: &mut [f32],
) {
    let mut acc = [0i32; W];
    for (k, &v) in xrow.iter().enumerate() {
        let wrow: &[i8; W] =
            panel[k * W..k * W + W].try_into().expect("panel width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v as i32 * wv as i32;
        }
    }
    for ((o, &a), &s) in out[..W].iter_mut().zip(acc.iter()).zip(w_scales)
    {
        *o = a as f32 * x_scale * s;
    }
}

/// Runtime-width `(row, panel)` microkernel (ragged last panel and
/// non-specialized widths).
#[inline(always)]
fn row_panel_dyn(
    xrow: &[i8],
    panel: &[i8],
    tw: usize,
    x_scale: f32,
    w_scales: &[f32],
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0i32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (k, &v) in xrow.iter().enumerate() {
        let wrow = &panel[k * tw..(k + 1) * tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v as i32 * wv as i32;
        }
    }
    for ((o, &a), &s) in out[..tw].iter_mut().zip(acc.iter()).zip(w_scales)
    {
        *o = a as f32 * x_scale * s;
    }
}

/// Panel-packed W8A8 matmul with **per-token** activation scales: the
/// quantized weight arrives in tile-panel layout (packed once at bind
/// from the cached `quantize_weight` output). Integer accumulation is
/// exact and the dequant expression matches, so the output is bitwise
/// identical to
/// [`reference::w8a8_per_token`](super::reference::w8a8_per_token).
pub fn w8a8_tiled_per_token_packed(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    out: &mut [f32],
) {
    assert_eq!(xq.len(), t * din, "activation shape");
    assert_eq!(wq.din, din, "weight contraction width");
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    assert_eq!(w_scales.len(), wq.dout, "one weight scale per column");
    assert_eq!(out.len(), t * wq.dout, "output shape");
    let dout = wq.dout;
    for r in 0..t {
        let xrow = &xq[r * din..(r + 1) * din];
        let xs = x_scales[r];
        let orow = &mut out[r * dout..(r + 1) * dout];
        for p in 0..wq.n_panels() {
            let (c0, tw, panel) = wq.panel(p);
            let ws = &w_scales[c0..c0 + tw];
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_panel::<4>(xrow, panel, xs, ws, ot),
                8 => row_panel::<8>(xrow, panel, xs, ws, ot),
                16 => row_panel::<16>(xrow, panel, xs, ws, ot),
                32 => row_panel::<32>(xrow, panel, xs, ws, ot),
                _ => row_panel_dyn(xrow, panel, tw, xs, ws, ot),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tiled_matches_reference_across_tile_widths() {
        let mut rng = Rng::new(17);
        let (t, din, dout) = (6usize, 32usize, 21usize);
        let xq: Vec<i8> = (0..t * din)
            .map(|_| (rng.below(255) as i32 - 127) as i8)
            .collect();
        let wq: Vec<i8> = (0..din * dout)
            .map(|_| (rng.below(255) as i32 - 127) as i8)
            .collect();
        let ws: Vec<f32> =
            (0..dout).map(|_| rng.f64() as f32 * 0.01 + 1e-4).collect();
        let xs: Vec<f32> =
            (0..t).map(|_| rng.f64() as f32 * 0.1 + 1e-4).collect();
        let golden = reference::w8a8_per_token(
            &xq, t, din, &wq, dout, &xs, &ws,
        );
        for tile in [1usize, 4, 7, 8, 16, 32, 64] {
            let mut out = vec![0.0f32; t * dout];
            w8a8_tiled_per_token(
                &xq, t, din, &wq, dout, tile, &xs, &ws, &mut out,
            );
            assert_eq!(out, golden, "tile {tile}");
        }
        // panel-packed: pure layout transform, same bits
        for pw in [1usize, 4, 7, 8, 16, 32] {
            let packed = PackedPanels::pack(&wq, din, dout, pw);
            let mut out = vec![0.0f32; t * dout];
            w8a8_tiled_per_token_packed(
                &xq, t, din, &packed, &xs, &ws, &mut out,
            );
            assert_eq!(out, golden, "panel_w {pw}");
        }
        // per-tensor == per-token with a broadcast scale
        let golden_pt =
            reference::w8a8(&xq, t, din, &wq, dout, 0.05, &ws);
        let mut out = vec![0.0f32; t * dout];
        w8a8_tiled(&xq, t, din, &wq, dout, 8, 0.05, &ws, &mut out);
        assert_eq!(out, golden_pt);
    }
}
