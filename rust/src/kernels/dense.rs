//! Register-tiled dense matmul: the true-dense baseline, cache-blocked.
//!
//! Performs the full `t·din·dout` multiply-adds unconditionally (no
//! zero skipping — a pruned input cannot make the baseline silently
//! sparse), with the same `dout`-tile accumulator scheme as the N:M
//! kernel. See the [module docs](crate::kernels) for the tiling scheme
//! and the bitwise-parity argument against
//! [`reference::dense`](super::reference::dense).

use super::pack::PackedPanels;
use super::{clamp_tile, MAX_DOUT_TILE};

/// One `(row, tile)` microkernel at const width `W`.
#[inline(always)]
fn row_tile<const W: usize>(
    xrow: &[f32],
    w: &[f32],
    dout: usize,
    c0: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; W];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow: &[f32; W] =
            w[start..start + W].try_into().expect("tile width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..W].copy_from_slice(&acc);
}

/// Runtime-width `(row, tile)` microkernel for ragged tails and
/// non-specialized tile widths.
#[inline(always)]
fn row_tile_dyn(
    xrow: &[f32],
    w: &[f32],
    dout: usize,
    c0: usize,
    tw: usize,
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0.0f32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow = &w[start..start + tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..tw].copy_from_slice(acc);
}

/// Tiled dense matmul: row-major `x [t, din] @ w [din, dout]` written
/// into `out` (`[t, dout]`, fully overwritten). Bitwise identical to
/// [`reference::dense`](super::reference::dense) for every `dout_tile`.
pub fn dense_tiled(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    dout_tile: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.len(), din * dout, "weight shape");
    assert_eq!(out.len(), t * dout, "output shape");
    let tile = clamp_tile(dout_tile);
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut c0 = 0;
        while c0 < dout {
            let tw = tile.min(dout - c0);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_tile::<4>(xrow, w, dout, c0, ot),
                8 => row_tile::<8>(xrow, w, dout, c0, ot),
                16 => row_tile::<16>(xrow, w, dout, c0, ot),
                32 => row_tile::<32>(xrow, w, dout, c0, ot),
                _ => row_tile_dyn(xrow, w, dout, c0, tw, ot),
            }
            c0 += tw;
        }
    }
}

/// One `(row, panel)` microkernel at const width `W` over a packed
/// panel: consecutive contraction steps read adjacent memory
/// (`panel[k*W..][..W]`), so the whole pass is one sequential sweep.
#[inline(always)]
fn row_panel<const W: usize>(xrow: &[f32], panel: &[f32], out: &mut [f32]) {
    let mut acc = [0.0f32; W];
    for (k, &v) in xrow.iter().enumerate() {
        let wrow: &[f32; W] =
            panel[k * W..k * W + W].try_into().expect("panel width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..W].copy_from_slice(&acc);
}

/// Runtime-width `(row, panel)` microkernel (ragged last panel and
/// non-specialized widths).
#[inline(always)]
fn row_panel_dyn(xrow: &[f32], panel: &[f32], tw: usize, out: &mut [f32]) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0.0f32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (k, &v) in xrow.iter().enumerate() {
        let wrow = &panel[k * tw..(k + 1) * tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..tw].copy_from_slice(acc);
}

/// Panel-packed dense matmul: `x [t, din] @ w [din, dout]` with the
/// weight in tile-panel layout. Same per-element ascending-`k`
/// reduction chain as [`dense_tiled`] at `dout_tile = panel_w`, so the
/// output is bitwise identical to
/// [`reference::dense`](super::reference::dense) — the panel layout is
/// a pure layout transform.
pub fn dense_tiled_packed(
    x: &[f32],
    t: usize,
    din: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.din, din, "weight contraction width");
    assert_eq!(out.len(), t * w.dout, "output shape");
    let dout = w.dout;
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_panel::<4>(xrow, panel, ot),
                8 => row_panel::<8>(xrow, panel, ot),
                16 => row_panel::<16>(xrow, panel, ot),
                32 => row_panel::<32>(xrow, panel, ot),
                _ => row_panel_dyn(xrow, panel, tw, ot),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn packed_matches_reference_across_panel_widths() {
        let mut rng = Rng::new(15);
        let (t, din, dout) = (6usize, 24usize, 37usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let golden = reference::dense(&x, t, din, &w, dout);
        for pw in [1usize, 3, 4, 8, 16, 32, 64] {
            let packed = PackedPanels::pack(&w, din, dout, pw);
            let mut out = vec![0.0f32; t * dout];
            dense_tiled_packed(&x, t, din, &packed, &mut out);
            assert_eq!(out, golden, "panel_w {pw}");
        }
    }

    #[test]
    fn tiled_matches_reference_across_tile_widths() {
        let mut rng = Rng::new(13);
        let (t, din, dout) = (7usize, 24usize, 29usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let golden = reference::dense(&x, t, din, &w, dout);
        for tile in [1usize, 3, 4, 8, 11, 16, 32, 64, 1000] {
            let mut out = vec![0.0f32; t * dout];
            dense_tiled(&x, t, din, &w, dout, tile, &mut out);
            assert_eq!(out, golden, "tile {tile}");
        }
    }
}
