//! Register-tiled dense matmul: the true-dense baseline, cache-blocked.
//!
//! Performs the full `t·din·dout` multiply-adds unconditionally (no
//! zero skipping — a pruned input cannot make the baseline silently
//! sparse), with the same `dout`-tile accumulator scheme as the N:M
//! kernel. See the [module docs](crate::kernels) for the tiling scheme
//! and the bitwise-parity argument against
//! [`reference::dense`](super::reference::dense).

use super::{clamp_tile, MAX_DOUT_TILE};

/// One `(row, tile)` microkernel at const width `W`.
#[inline(always)]
fn row_tile<const W: usize>(
    xrow: &[f32],
    w: &[f32],
    dout: usize,
    c0: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; W];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow: &[f32; W] =
            w[start..start + W].try_into().expect("tile width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..W].copy_from_slice(&acc);
}

/// Runtime-width `(row, tile)` microkernel for ragged tails and
/// non-specialized tile widths.
#[inline(always)]
fn row_tile_dyn(
    xrow: &[f32],
    w: &[f32],
    dout: usize,
    c0: usize,
    tw: usize,
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0.0f32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (k, &v) in xrow.iter().enumerate() {
        let start = k * dout + c0;
        let wrow = &w[start..start + tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..tw].copy_from_slice(acc);
}

/// Tiled dense matmul: row-major `x [t, din] @ w [din, dout]` written
/// into `out` (`[t, dout]`, fully overwritten). Bitwise identical to
/// [`reference::dense`](super::reference::dense) for every `dout_tile`.
pub fn dense_tiled(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    dout_tile: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.len(), din * dout, "weight shape");
    assert_eq!(out.len(), t * dout, "output shape");
    let tile = clamp_tile(dout_tile);
    for r in 0..t {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut c0 = 0;
        while c0 < dout {
            let tw = tile.min(dout - c0);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_tile::<4>(xrow, w, dout, c0, ot),
                8 => row_tile::<8>(xrow, w, dout, c0, ot),
                16 => row_tile::<16>(xrow, w, dout, c0, ot),
                32 => row_tile::<32>(xrow, w, dout, c0, ot),
                _ => row_tile_dyn(xrow, w, dout, c0, tw, ot),
            }
            c0 += tw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tiled_matches_reference_across_tile_widths() {
        let mut rng = Rng::new(13);
        let (t, din, dout) = (7usize, 24usize, 29usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let golden = reference::dense(&x, t, din, &w, dout);
        for tile in [1usize, 3, 4, 8, 11, 16, 32, 64, 1000] {
            let mut out = vec![0.0f32; t * dout];
            dense_tiled(&x, t, din, &w, dout, tile, &mut out);
            assert_eq!(out, golden, "tile {tile}");
        }
    }
}
