//! Register-tiled N:M SpMM over the compressed `(value, index)` row
//! format of [`crate::sparsity::spmm::NmCompressed`].
//!
//! Exact N:M makes every row's nonzero count a compile-visible constant
//! (`din·n/m`), so the compressed walk is a branch-free fixed-stride
//! scan; the only branch kept is the `v == 0.0` skip the reference
//! kernel performs (required for bitwise parity — a surviving channel
//! can legitimately hold `0.0`, and skipping it is not a no-op for
//! `-0.0` accumulators). See the [module docs](crate::kernels) for the
//! tiling scheme and the bitwise-parity argument.

use super::pack::PackedPanels;
use super::{clamp_tile, MAX_DOUT_TILE};

/// One `(row, tile)` microkernel at const width `W`: `W` accumulators
/// in registers, streamed over the row's compressed nonzeros.
#[inline(always)]
fn row_tile<const W: usize>(
    vals: &[f32],
    idx: &[u32],
    w: &[f32],
    dout: usize,
    c0: usize,
    out: &mut [f32],
) {
    let mut acc = [0.0f32; W];
    for (&v, &ci) in vals.iter().zip(idx.iter()) {
        if v == 0.0 {
            continue;
        }
        let start = ci as usize * dout + c0;
        let wrow: &[f32; W] =
            w[start..start + W].try_into().expect("tile width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..W].copy_from_slice(&acc);
}

/// Runtime-width `(row, tile)` microkernel for ragged tails and
/// non-specialized tile widths; accumulators live in one stack array.
#[inline(always)]
fn row_tile_dyn(
    vals: &[f32],
    idx: &[u32],
    w: &[f32],
    dout: usize,
    c0: usize,
    tw: usize,
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0.0f32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (&v, &ci) in vals.iter().zip(idx.iter()) {
        if v == 0.0 {
            continue;
        }
        let start = ci as usize * dout + c0;
        let wrow = &w[start..start + tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..tw].copy_from_slice(acc);
}

/// Tiled compressed SpMM: `rows` compressed token rows of exactly
/// `per_row` `(value, channel-index)` pairs each, against a row-major
/// `[din, dout]` weight, written into `out` (`[rows, dout]`, fully
/// overwritten). Bitwise identical to
/// [`reference::spmm_nm`](super::reference::spmm_nm) for every
/// `dout_tile`.
#[allow(clippy::too_many_arguments)]
pub fn spmm_nm_tiled(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &[f32],
    dout: usize,
    dout_tile: usize,
    out: &mut [f32],
) {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    assert_eq!(out.len(), rows * dout, "output shape");
    let tile = clamp_tile(dout_tile);
    for r in 0..rows {
        let vals = &values[r * per_row..(r + 1) * per_row];
        let idx = &index[r * per_row..(r + 1) * per_row];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut c0 = 0;
        while c0 < dout {
            let tw = tile.min(dout - c0);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_tile::<4>(vals, idx, w, dout, c0, ot),
                8 => row_tile::<8>(vals, idx, w, dout, c0, ot),
                16 => row_tile::<16>(vals, idx, w, dout, c0, ot),
                32 => row_tile::<32>(vals, idx, w, dout, c0, ot),
                _ => row_tile_dyn(vals, idx, w, dout, c0, tw, ot),
            }
            c0 += tw;
        }
    }
}

/// One `(row, panel)` microkernel at const width `W` over a packed
/// panel: the compressed walk stays fixed-stride, and each surviving
/// channel's `W`-wide weight row is `panel[ci*W..][..W]` — the panel
/// is revisited in ascending-channel order with no `dout` stride.
#[inline(always)]
fn row_panel<const W: usize>(
    vals: &[f32],
    idx: &[u32],
    panel: &[f32],
    out: &mut [f32],
) {
    let mut acc = [0.0f32; W];
    for (&v, &ci) in vals.iter().zip(idx.iter()) {
        if v == 0.0 {
            continue;
        }
        let start = ci as usize * W;
        let wrow: &[f32; W] =
            panel[start..start + W].try_into().expect("panel width");
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..W].copy_from_slice(&acc);
}

/// Runtime-width `(row, panel)` microkernel (ragged last panel and
/// non-specialized widths).
#[inline(always)]
fn row_panel_dyn(
    vals: &[f32],
    idx: &[u32],
    panel: &[f32],
    tw: usize,
    out: &mut [f32],
) {
    debug_assert!(tw <= MAX_DOUT_TILE);
    let mut buf = [0.0f32; MAX_DOUT_TILE];
    let acc = &mut buf[..tw];
    for (&v, &ci) in vals.iter().zip(idx.iter()) {
        if v == 0.0 {
            continue;
        }
        let start = ci as usize * tw;
        let wrow = &panel[start..start + tw];
        for (a, &wv) in acc.iter_mut().zip(wrow.iter()) {
            *a += v * wv;
        }
    }
    out[..tw].copy_from_slice(acc);
}

/// Panel-packed compressed SpMM: same contract as [`spmm_nm_tiled`]
/// with the weight in tile-panel layout. Each output element keeps its
/// ascending-`k` reduction chain (the panel transform only changes
/// *where* a weight row lives, not *when* it is added), so the output
/// is bitwise identical to
/// [`reference::spmm_nm`](super::reference::spmm_nm).
pub fn spmm_nm_tiled_packed(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &PackedPanels<f32>,
    out: &mut [f32],
) {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    assert_eq!(out.len(), rows * w.dout, "output shape");
    let dout = w.dout;
    for r in 0..rows {
        let vals = &values[r * per_row..(r + 1) * per_row];
        let idx = &index[r * per_row..(r + 1) * per_row];
        let orow = &mut out[r * dout..(r + 1) * dout];
        for p in 0..w.n_panels() {
            let (c0, tw, panel) = w.panel(p);
            let ot = &mut orow[c0..c0 + tw];
            match tw {
                4 => row_panel::<4>(vals, idx, panel, ot),
                8 => row_panel::<8>(vals, idx, panel, ot),
                16 => row_panel::<16>(vals, idx, panel, ot),
                32 => row_panel::<32>(vals, idx, panel, ot),
                _ => row_panel_dyn(vals, idx, panel, tw, ot),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tiled_matches_reference_across_tile_widths() {
        let mut rng = Rng::new(11);
        let (rows, din, dout, n, m) = (5usize, 32usize, 37usize, 2, 4);
        let per_row = din / m * n;
        // synthetic compressed rows: two survivors per group of four,
        // including an explicit 0.0 survivor to exercise the skip branch
        let mut values = Vec::new();
        let mut index = Vec::new();
        for r in 0..rows {
            for g in 0..din / m {
                for j in 0..n {
                    let v = if (r + g + j) % 7 == 0 {
                        0.0
                    } else {
                        rng.normal() as f32
                    };
                    values.push(v);
                    index.push((g * m + 2 * j) as u32);
                }
            }
        }
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32).collect();
        let golden =
            reference::spmm_nm(&values, &index, rows, per_row, &w, dout);
        for tile in [1usize, 3, 4, 5, 8, 16, 32, 64, 1000] {
            let mut out = vec![0.0f32; rows * dout];
            spmm_nm_tiled(
                &values, &index, rows, per_row, &w, dout, tile, &mut out,
            );
            assert_eq!(out, golden, "tile {tile}");
        }
        // panel-packed: pure layout transform, same bits
        for pw in [1usize, 4, 5, 8, 16, 32] {
            let packed = PackedPanels::pack(&w, din, dout, pw);
            let mut out = vec![0.0f32; rows * dout];
            spmm_nm_tiled_packed(
                &values, &index, rows, per_row, &packed, &mut out,
            );
            assert_eq!(out, golden, "panel_w {pw}");
        }
    }
}
