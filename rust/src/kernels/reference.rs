//! The retained naive loops the tiled kernels are pinned against.
//!
//! These are the pre-tiling kernels, verbatim: the per-channel axpy
//! SpMM/dense loops and the per-output-element int8 dot product. They
//! are kept public (not `#[cfg(test)]`) because both the
//! `tests/kernel_parity.rs` property suite and the `spmm` bench's
//! reference-vs-tiled series consume them from outside the crate.
//! They define the float-op order contract: the tiled kernels must be
//! **bitwise identical** to these for every shape and tile width.

/// Reference compressed N:M SpMM: per-channel axpy over the full
/// output row, skipping stored zeros (the surviving-channel `0.0`
/// case) — the original `NmCompressed::matmul` loop.
pub fn spmm_nm(
    values: &[f32],
    index: &[u32],
    rows: usize,
    per_row: usize,
    w: &[f32],
    dout: usize,
) -> Vec<f32> {
    assert_eq!(values.len(), rows * per_row, "values shape");
    assert_eq!(index.len(), rows * per_row, "index shape");
    let mut out = vec![0.0f32; rows * dout];
    for r in 0..rows {
        let orow = &mut out[r * dout..(r + 1) * dout];
        let base = r * per_row;
        for k in 0..per_row {
            let v = values[base + k];
            if v == 0.0 {
                continue;
            }
            let c = index[base + k] as usize;
            let wrow = &w[c * dout..(c + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += v * wv;
            }
        }
    }
    out
}

/// Reference dense matmul: per-channel axpy over the full output row,
/// no zero skipping — the original `dense_matmul` loop.
pub fn dense(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), t * din, "activation shape");
    assert_eq!(w.len(), din * dout, "weight shape");
    let mut out = vec![0.0f32; t * dout];
    for r in 0..t {
        let orow = &mut out[r * dout..(r + 1) * dout];
        let xrow = &x[r * din..(r + 1) * din];
        for (c, &v) in xrow.iter().enumerate() {
            let wrow = &w[c * dout..(c + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += v * wv;
            }
        }
    }
    out
}

/// Reference W8A8 matmul with a per-tensor activation scale: one i32
/// dot product per output element — the original `quant::w8a8_matmul`
/// loop.
pub fn w8a8(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    x_scale: f32,
    w_scales: &[f32],
) -> Vec<f32> {
    let mut out = vec![0f32; t * dout];
    for r in 0..t {
        for c in 0..dout {
            let mut acc: i32 = 0;
            for k in 0..din {
                acc += xq[r * din + k] as i32 * wq[k * dout + c] as i32;
            }
            out[r * dout + c] = acc as f32 * x_scale * w_scales[c];
        }
    }
    out
}

/// Reference W8A8 matmul with per-token activation scales: the same
/// dot-product loop with `x_scales[r]` fused at dequant.
pub fn w8a8_per_token(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    x_scales: &[f32],
    w_scales: &[f32],
) -> Vec<f32> {
    assert_eq!(x_scales.len(), t, "one activation scale per token row");
    let mut out = vec![0f32; t * dout];
    for r in 0..t {
        for c in 0..dout {
            let mut acc: i32 = 0;
            for k in 0..din {
                acc += xq[r * din + k] as i32 * wq[k * dout + c] as i32;
            }
            out[r * dout + c] = acc as f32 * x_scales[r] * w_scales[c];
        }
    }
    out
}
