//! Table harnesses (paper Tables 1-3 + Appendix A Table 1).
//!
//! Backend-neutral: each harness asks `runtime::engine_for` for the
//! default engine over the artifacts directory (native CPU unless a
//! caller wires up PJRT) and drives it through the `Engine` trait.

use anyhow::Result;

use super::ReproCtx;
use crate::eval::{eval_generation, eval_multiple_choice, load_task};
use crate::runtime::{engine_for, Engine, Manifest};
use crate::sparsity::policy::Setting;
use crate::util::fmt::{acc, pct_drop, Table};

/// The N:M ratios every table sweeps.
pub const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];

/// Zero-shot MC task order of the paper's tables.
const MC_ORDER: [(&str, &str); 9] = [
    ("arc_challenge", "AC"),
    ("arc_easy", "AE"),
    ("boolq", "BQ"),
    ("mmlu", "MMLU"),
    ("ceval", "CEVAL"),
    ("obqa", "OBQA"),
    ("piqa", "PIQA"),
    ("rte", "RTE"),
    ("winogrande", "WG"),
];

fn models(ctx: &ReproCtx, manifest: &Manifest) -> Vec<String> {
    match &ctx.model {
        Some(m) => vec![m.clone()],
        None => manifest.models.keys().cloned().collect(),
    }
}

/// Which MC tasks a model is evaluated on (CEVAL only for the
/// B-subspace-trained Qwen analogue, like the paper).
fn tasks_for(model: &str) -> Vec<&'static str> {
    MC_ORDER
        .iter()
        .map(|(t, _)| *t)
        .filter(|t| *t != "ceval" || model == "tiny-lm-b" || model == "tiny-moe")
        .collect()
}

fn settings_for(model: &str, is_moe: bool) -> Vec<Setting> {
    let _ = model;
    if is_moe {
        vec![Setting::Naive, Setting::LayerSkip]
    } else {
        vec![Setting::Naive, Setting::LayerSkip, Setting::All]
    }
}

/// Evaluate the zero-shot row set for one (model, quantized?) grid.
fn zero_shot_table(ctx: &ReproCtx, sq: bool, title: &str) -> Result<()> {
    let mut rt = engine_for(ctx.artifacts)?;
    for model in models(ctx, rt.manifest()) {
        let info = rt.manifest().models.get(&model).unwrap().clone();
        if sq && info.is_moe {
            // the paper's MoE W8A8 uses per-token dynamic quantization
            // (not lowered here; see DESIGN.md substitutions)
            continue;
        }
        let tasks = tasks_for(&model);
        let weights = if sq {
            format!("{model}.sq.atw")
        } else {
            format!("{model}.atw")
        };
        let infix = if sq { "sq" } else { "dense" };
        let mut table = Table::new(
            &format!("{title} — {model}"),
            &[&["Rt.", "Settings"],
              tasks
                  .iter()
                  .map(|t| {
                      MC_ORDER.iter().find(|(n, _)| n == t).unwrap().1
                  })
                  .collect::<Vec<_>>()
                  .as_slice(),
              &["Avg.", "Drop"]]
                .concat(),
        );
        // baseline
        let base_art = format!("{model}.prefill64.{infix}");
        let binding = rt.bind(&base_art, &[&weights])?;
        let mut base_accs = Vec::new();
        for t in &tasks {
            let set = load_task(ctx.artifacts, &format!("{t}.aev"))?;
            let r = eval_multiple_choice(
                &mut *rt,
                &base_art,
                &binding,
                t,
                &set,
                ctx.limit,
            )?;
            base_accs.push(r.accuracy);
        }
        let base_avg =
            base_accs.iter().sum::<f64>() / base_accs.len() as f64;
        let mut row = vec![
            "-".to_string(),
            if sq { "SQ-W8A8" } else { "Bfloat16*" }.to_string(),
        ];
        row.extend(base_accs.iter().map(|a| acc(*a)));
        row.push(acc(base_avg));
        row.push("-".to_string());
        table.row(row);

        for (n, m) in RATIOS {
            for setting in settings_for(&model, info.is_moe) {
                let variant = if sq { "sq_nm" } else { "nm" };
                let art = format!("{model}.prefill64.{variant}{n}_{m}");
                let aux = setting.aux_file(&model, sq);
                let b = rt.bind(&art, &[&weights, &aux])?;
                let mut accs = Vec::new();
                for t in &tasks {
                    let set =
                        load_task(ctx.artifacts, &format!("{t}.aev"))?;
                    let r = eval_multiple_choice(
                        &mut *rt,
                        &art,
                        &b,
                        t,
                        &set,
                        ctx.limit,
                    )?;
                    accs.push(r.accuracy);
                }
                let avg = accs.iter().sum::<f64>() / accs.len() as f64;
                let mut row =
                    vec![format!("{n}:{m}"), setting.label().to_string()];
                row.extend(accs.iter().map(|a| acc(*a)));
                row.push(acc(avg));
                row.push(pct_drop(base_avg, avg));
                table.row(row);
            }
        }
        table.print();
    }
    Ok(())
}

/// Table 1: Amber Pruner (fp) on zero-shot tasks.
pub fn table1(ctx: &ReproCtx) -> Result<()> {
    zero_shot_table(ctx, false, "Table 1: Amber Pruner on Zero-shot tasks")
}

/// Table 2: Outstanding-sparse (W8A8) on zero-shot tasks.
pub fn table2(ctx: &ReproCtx) -> Result<()> {
    zero_shot_table(
        ctx,
        true,
        "Table 2: Outstanding-sparse on Zero-shot tasks",
    )
}

/// Table 3: Few-shot (GSM8K analogue) + LongBench analogues, fp and W8A8.
pub fn table3(ctx: &ReproCtx) -> Result<()> {
    let mut rt = engine_for(ctx.artifacts)?;
    for model in models(ctx, rt.manifest()) {
        let info = rt.manifest().models.get(&model).unwrap().clone();
        for sq in [false, true] {
            if sq && info.is_moe {
                continue;
            }
            let weights = if sq {
                format!("{model}.sq.atw")
            } else {
                format!("{model}.atw")
            };
            let label = if sq { "Outstanding-sparse" } else { "Amber Pruner" };
            let infix = if sq { "sq" } else { "dense" };
            let decode_art = format!(
                "{model}.decode.{}",
                if sq { "sq" } else { "dense" }
            );
            let dec_b = rt.bind(&decode_art, &[&weights])?;
            let mut table = Table::new(
                &format!("Table 3 ({label}) — {model}"),
                &["Rt.", "Settings", "GSM8K", "Drop", "LB avg", "Drop"],
            );
            let gen_limit = if ctx.limit == 0 { 0 } else { ctx.limit };
            let run_cell = |rt: &mut dyn Engine,
                            prefill: &str,
                            binding: &str,
                            task: &str,
                            seq: usize|
             -> Result<f64> {
                let _ = seq;
                let set = load_task(ctx.artifacts, &format!("{task}.aev"))?;
                let r = eval_generation(
                    rt, prefill, binding, &decode_art, &dec_b, task, &set,
                    gen_limit,
                )?;
                Ok(r.accuracy)
            };
            // baseline
            let p64 = format!("{model}.prefill64.{infix}");
            let p256 = format!("{model}.prefill256.{infix}");
            let b64 = rt.bind(&p64, &[&weights])?;
            let b256 = rt.bind(&p256, &[&weights])?;
            let g0 = run_cell(&mut *rt, &p64, &b64, "gsm8k", 64)?;
            let lk0 =
                run_cell(&mut *rt, &p256, &b256, "longbench_kv", 256)?;
            let li0 =
                run_cell(&mut *rt, &p256, &b256, "longbench_ind", 256)?;
            let lb0 = (lk0 + li0) / 2.0;
            table.row(vec![
                "-".into(),
                "Baseline".into(),
                acc(g0),
                "-".into(),
                acc(lb0),
                "-".into(),
            ]);
            for (n, m) in RATIOS {
                for setting in settings_for(&model, info.is_moe) {
                    let variant = if sq { "sq_nm" } else { "nm" };
                    let a64 = format!("{model}.prefill64.{variant}{n}_{m}");
                    let a256 =
                        format!("{model}.prefill256.{variant}{n}_{m}");
                    let aux = setting.aux_file(&model, sq);
                    let b64 = rt.bind(&a64, &[&weights, &aux])?;
                    let b256 = rt.bind(&a256, &[&weights, &aux])?;
                    let g =
                        run_cell(&mut *rt, &a64, &b64, "gsm8k", 64)?;
                    let lk = run_cell(
                        &mut *rt,
                        &a256,
                        &b256,
                        "longbench_kv",
                        256,
                    )?;
                    let li = run_cell(
                        &mut *rt,
                        &a256,
                        &b256,
                        "longbench_ind",
                        256,
                    )?;
                    let lb = (lk + li) / 2.0;
                    table.row(vec![
                        format!("{n}:{m}"),
                        setting.label().to_string(),
                        acc(g),
                        pct_drop(g0, g),
                        acc(lb),
                        pct_drop(lb0, lb),
                    ]);
                }
            }
            table.print();
        }
    }
    Ok(())
}

/// Appendix A Table 1: weight sparsification (SparseGPT / Wanda /
/// Pruner-Zero) vs naive top-k ACTIVATION sparsity, on tiny-lm-a, no layer
/// skipping — weight methods reuse the *dense* executable with pruned
/// weight files.
pub fn app_table1(ctx: &ReproCtx) -> Result<()> {
    let mut rt = engine_for(ctx.artifacts)?;
    let model = "tiny-lm-a".to_string();
    let tasks = tasks_for(&model);
    let mut table = Table::new(
        "Appendix A Table 1: weight vs activation sparsity (tiny-lm-a)",
        &[&["Rt.", "Method"],
          tasks
              .iter()
              .map(|t| MC_ORDER.iter().find(|(n, _)| n == t).unwrap().1)
              .collect::<Vec<_>>()
              .as_slice(),
          &["Avg.", "Drop"]]
            .concat(),
    );
    let dense_art = format!("{model}.prefill64.dense");
    let weights = format!("{model}.atw");
    let eval_all = |rt: &mut dyn Engine,
                    art: &str,
                    binding: &str|
     -> Result<Vec<f64>> {
        tasks
            .iter()
            .map(|t| {
                let set = load_task(ctx.artifacts, &format!("{t}.aev"))?;
                Ok(eval_multiple_choice(
                    rt, art, binding, t, &set, ctx.limit,
                )?
                .accuracy)
            })
            .collect()
    };
    let b = rt.bind(&dense_art, &[&weights])?;
    let base = eval_all(&mut *rt, &dense_art, &b)?;
    let base_avg = base.iter().sum::<f64>() / base.len() as f64;
    let mut row = vec!["-".into(), "Baseline: float32".into()];
    row.extend(base.iter().map(|a| acc(*a)));
    row.push(acc(base_avg));
    row.push("-".into());
    table.row(row);
    for (n, m) in [(2, 4), (4, 8)] {
        // activation: naive top-k through the nm executable
        let art = format!("{model}.prefill64.nm{n}_{m}");
        let aux = Setting::Naive.aux_file(&model, false);
        let b = rt.bind(&art, &[&weights, &aux])?;
        let accs = eval_all(&mut *rt, &art, &b)?;
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![
            format!("{n}:{m}"),
            "Activation: Naive top-k".to_string(),
        ];
        row.extend(accs.iter().map(|a| acc(*a)));
        row.push(acc(avg));
        row.push(pct_drop(base_avg, avg));
        table.row(row);
        // weight sparsity baselines: same dense executable, pruned weights
        for method in ["sparsegpt", "wanda", "prunerzero", "magnitude"] {
            let wfile = format!("{model}.wsp_{method}_{n}_{m}.atw");
            let b = rt.bind(&dense_art, &[&wfile])?;
            let accs = eval_all(&mut *rt, &dense_art, &b)?;
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            let mut row = vec![
                format!("{n}:{m}"),
                format!("Weight: {method}"),
            ];
            row.extend(accs.iter().map(|a| acc(*a)));
            row.push(acc(avg));
            row.push(pct_drop(base_avg, avg));
            table.row(row);
        }
    }
    table.print();
    Ok(())
}
