//! Regeneration harnesses for every table and figure in the paper
//! (DESIGN.md §4 experiment index). Each entry prints the same rows /
//! series the paper reports, measured on this testbed's substitute models.
//!
//!   amber repro table1      Zero-shot, Amber Pruner        (paper Tab. 1)
//!   amber repro table2      Zero-shot, Outstanding-sparse  (paper Tab. 2)
//!   amber repro table3      GSM8K + LongBench              (paper Tab. 3)
//!   amber repro app-table1  weight vs activation sparsity  (App. A Tab. 1)
//!   amber repro fig2        act/weight distributions       (paper Fig. 2)
//!   amber repro fig34       Outstanding-sparse ranges      (Figs. 3-4)
//!   amber repro fig6        sensitivity per projection     (App. D Fig. 6)
//!   amber repro appc        per-module activation stats    (App. C)
//!   amber repro coverage    % linear FLOPs accelerated     (§Setup claim)

pub mod figures;
pub mod tables;

use std::path::Path;

use anyhow::{bail, Result};

/// Shared context every repro harness receives.
pub struct ReproCtx<'a> {
    /// artifacts directory (manifest + eval datasets + stats)
    pub artifacts: &'a Path,
    /// samples per task (0 = full dataset)
    pub limit: usize,
    /// restrict to a single model (None = all in manifest)
    pub model: Option<String>,
}

/// Run one repro target by name (see module docs for the index).
pub fn run(what: &str, ctx: &ReproCtx) -> Result<()> {
    match what {
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "app-table1" => tables::app_table1(ctx),
        "fig2" => figures::fig2(ctx),
        "fig34" => figures::fig34(ctx),
        "fig6" => figures::fig6(ctx),
        "appc" => figures::appc(ctx),
        "coverage" => figures::coverage(ctx),
        "tpu-model" => figures::tpu_model(ctx),
        "ablation" => figures::ablation(ctx),
        "all" => {
            for t in [
                "coverage", "tpu-model", "fig2", "fig34", "fig6", "appc",
                "table1", "table2", "table3", "app-table1",
            ] {
                run(t, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown repro target '{other}'"),
    }
}
