//! Figure harnesses: distribution statistics (Figs. 2-4, Appendix C),
//! sensitivity series (Appendix D / Fig. 6) and the coverage headline.
//! Data series are computed at build time (python, on real activations)
//! into artifacts/stats/*.json; these harnesses render the same series the
//! figures plot, as text.

use anyhow::{Context, Result};

use super::ReproCtx;
use crate::sparsity::coverage::Geometry;
use crate::runtime::Manifest;
use crate::util::fmt::Table;
use crate::util::json::Json;

fn load_stats(ctx: &ReproCtx, file: &str) -> Result<Json> {
    let p = ctx.artifacts.join("stats").join(file);
    let text = std::fs::read_to_string(&p)
        .with_context(|| format!("read {}", p.display()))?;
    Ok(Json::parse(&text)?)
}

fn models(ctx: &ReproCtx, manifest: &Manifest) -> Vec<String> {
    match &ctx.model {
        Some(m) => vec![m.clone()],
        None => manifest.models.keys().cloned().collect(),
    }
}

fn bar(frac: f64, width: usize) -> String {
    let n = (frac * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

/// Fig. 2: activations vs weights of the gate projection — activations
/// carry far more near-zero mass (the motivation for *activation* N:M).
pub fn fig2(ctx: &ReproCtx) -> Result<()> {
    let manifest = Manifest::load(ctx.artifacts)?;
    for model in models(ctx, &manifest) {
        let j = load_stats(ctx, &format!("dist_{model}.json"))?;
        let act = j.req("activation_gate")?;
        let w = j.req("weight_gate")?;
        println!(
            "\n== Fig 2: |value| distribution, gate_proj ({model}, layer {}) ==",
            j.req_usize("layer")?
        );
        println!(
            "near-zero (<5% of max) fraction:  activations {:.1}%   weights {:.1}%",
            act.req("near_zero_frac")?.as_f64().unwrap() * 100.0,
            w.req("near_zero_frac")?.as_f64().unwrap() * 100.0
        );
        let ah = act.req("hist")?.as_arr().unwrap();
        let wh = w.req("hist")?.as_arr().unwrap();
        let at: f64 = ah.iter().filter_map(|v| v.as_f64()).sum();
        let wt: f64 = wh.iter().filter_map(|v| v.as_f64()).sum();
        println!("|x|/max    activations            weights");
        for (i, (a, b)) in ah.iter().zip(wh.iter()).enumerate() {
            let fa = a.as_f64().unwrap_or(0.0) / at;
            let fb = b.as_f64().unwrap_or(0.0) / wt;
            println!(
                "{:>4.2}-{:<4.2} {:<22} {:<22}",
                i as f64 / 20.0,
                (i + 1) as f64 / 20.0,
                bar(fa, 20),
                bar(fb, 20)
            );
        }
    }
    Ok(())
}

/// Figs. 3-4: per-channel activation/weight |max| before and after the
/// Outstanding-sparse inverted smoothing (alpha = 0.10).
pub fn fig34(ctx: &ReproCtx) -> Result<()> {
    let manifest = Manifest::load(ctx.artifacts)?;
    for model in models(ctx, &manifest) {
        let Ok(j) = load_stats(ctx, &format!("sq_dist_{model}.json")) else {
            continue; // moe has no sq pipeline
        };
        let series = |node: &Json, key: &str| -> Vec<f64> {
            node.req(key)
                .ok()
                .and_then(|v| v.as_arr().map(|a| {
                    a.iter().filter_map(|x| x.as_f64()).collect()
                }))
                .unwrap_or_default()
        };
        let pre = j.req("pre")?;
        let post = j.req("post")?;
        let stats = |v: &[f64]| {
            let mx = v.iter().cloned().fold(0.0, f64::max);
            let mean = v.iter().sum::<f64>() / v.len().max(1) as f64;
            (mean, mx)
        };
        let (am0, ax0) = stats(&series(pre, "act_absmax"));
        let (am1, ax1) = stats(&series(post, "act_absmax"));
        let (wm0, wx0) = stats(&series(pre, "w_absmax"));
        let (wm1, wx1) = stats(&series(post, "w_absmax"));
        println!(
            "\n== Figs 3-4: Outstanding-sparse (alpha=0.10) pre/post — {model} =="
        );
        let mut t = Table::new(
            "per-channel |max| (gate_proj input / weights)",
            &["tensor", "pre mean", "pre max", "post mean", "post max"],
        );
        t.row(vec![
            "activations".into(),
            format!("{am0:.3}"),
            format!("{ax0:.3}"),
            format!("{am1:.3}"),
            format!("{ax1:.3}"),
        ]);
        t.row(vec![
            "weights".into(),
            format!("{wm0:.3}"),
            format!("{wx0:.3}"),
            format!("{wm1:.3}"),
            format!("{wx1:.3}"),
        ]);
        t.print();
        println!(
            "activation range expanded {:.2}x (inverted s = 1/s_j pushes \
             outliers INTO activations to sharpen top-k selectivity)",
            ax1 / ax0.max(1e-9)
        );
    }
    Ok(())
}

/// Appendix D / Fig. 6: average sensitivity e_q per projection type.
pub fn fig6(ctx: &ReproCtx) -> Result<()> {
    let manifest = Manifest::load(ctx.artifacts)?;
    for model in models(ctx, &manifest) {
        let j = load_stats(ctx, &format!("sensitivity_{model}.json"))?;
        let mm = j.req("module_mean")?.as_obj().unwrap();
        println!("\n== Fig 6 / Appendix D: mean sensitivity e_q — {model} ==");
        let mx = mm
            .values()
            .filter_map(|v| v.as_f64())
            .fold(0.0f64, f64::max);
        let mut entries: Vec<(&String, f64)> = mm
            .iter()
            .filter_map(|(k, v)| v.as_f64().map(|f| (k, f)))
            .collect();
        entries.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (name, v) in entries {
            println!("{:>10}: {:<30} {:.4}", name, bar(v / mx, 30), v);
        }
        let skips: Vec<usize> = j
            .req("skip_layers")?
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        println!("skip layers (q/gate): {skips:?}");
    }
    Ok(())
}

/// Appendix C: per-module activation statistics (heatmap summaries).
pub fn appc(ctx: &ReproCtx) -> Result<()> {
    let manifest = Manifest::load(ctx.artifacts)?;
    for model in models(ctx, &manifest) {
        let j = load_stats(ctx, &format!("dist_{model}.json"))?;
        println!("\n== Appendix C: module input statistics — {model} ==");
        for key in ["activation_q", "activation_gate", "activation_down"] {
            if let Ok(node) = j.req(key) {
                println!(
                    "{:>18}: near-zero {:>5.1}%  |max| {:.3}",
                    key,
                    node.req("near_zero_frac")?.as_f64().unwrap() * 100.0,
                    node.req("absmax")?.as_f64().unwrap()
                );
            }
        }
    }
    Ok(())
}

/// TPU perf model for the Layer-1 kernels (DESIGN.md §5): VMEM residency +
/// MXU utilization estimates for the dense vs fused-N:M grid steps, at
/// both the paper's LLaMA-8B geometry and our tiny substitute's.
pub fn tpu_model(_ctx: &ReproCtx) -> Result<()> {
    use crate::sparsity::estimate::{artifact_geometry, TpuParams};
    let p = TpuParams::default();
    let tokens = 4096; // prefill batch x seq at serving scale
    let mut t = Table::new(
        "L1 kernel estimates (per 128-token grid step, bf16, prefill 4096 tok)",
        &["projection", "VMEM", "VMEM%", "bound", "MXU util",
          "2:4 gp-hw", "2:4 spmm-unit", "8:16 spmm-unit"],
    );
    for (name, din, dout) in [
        ("llama8b q_proj", 4096usize, 4096usize),
        ("llama8b gate_proj", 4096, 14336),
        ("llama8b down_proj", 14336, 4096),
        ("tiny-lm-a gate_proj", 96, 384),
    ] {
        let g = artifact_geometry(din, dout, tokens);
        let d = g.estimate_dense(&p);
        let gp = g.estimate_nm(&p, 2, 4, false);
        let s24 = g.estimate_nm(&p, 2, 4, true);
        let s816 = g.estimate_nm(&p, 8, 16, true);
        t.row(vec![
            name.into(),
            format!("{:.1} KiB", d.vmem_bytes as f64 / 1024.0),
            format!("{:.1}%", d.vmem_frac * 100.0),
            d.bound.into(),
            format!("{:.2}", d.mxu_utilization),
            format!("{:.2}x", d.est_secs_per_step / gp.est_secs_per_step),
            format!("{:.2}x", d.est_secs_per_step / s24.est_secs_per_step),
            format!("{:.2}x", d.est_secs_per_step / s816.est_secs_per_step),
        ]);
    }
    t.print();
    println!(
        "gp-hw = general-purpose hardware (VPU top-k selector): ~1x,\n\
         matching the paper's 'current hardware … hinder[s] observed\n\
         acceleration gains'; spmm-unit = selector fused into the sparse\n\
         operand load path (the co-designed hardware the paper targets).\n\
         (interpret-mode CPU wall-clock is not an accelerator proxy; this\n\
         model is the structural L1 perf deliverable — EXPERIMENTS.md §Perf)"
    );
    Ok(())
}

/// Ablations (design-choice sweeps computed by `python -m
/// compile.ablation` on real calibration activations).
pub fn ablation(ctx: &ReproCtx) -> Result<()> {
    let j = load_stats(ctx, "ablation.json")?;
    println!("\n== Ablation A1: scoring method (mean relative output error) ==");
    let mut t = Table::new(
        "lower is better",
        &["ratio", "naive |x|", "Wanda-like (Eq.2)", "Robust-Norm (Eq.3-5)"],
    );
    if let Some(sc) = j.req("scoring")?.as_obj() {
        for (ratio, row) in sc {
            t.row(vec![
                ratio.clone(),
                format!("{:.4}", row.req("naive")?.as_f64().unwrap()),
                format!("{:.4}", row.req("wanda")?.as_f64().unwrap()),
                format!("{:.4}", row.req("robust")?.as_f64().unwrap()),
            ]);
        }
    }
    t.print();
    println!("\n== Ablation A2: Robust-Norm clip percentile (error @2:4) ==");
    if let Some(pc) = j.req("robust_percentile")?.as_obj() {
        for (q, v) in pc {
            println!("  clip q={q:<6} -> {:.4}", v.as_f64().unwrap());
        }
        println!("  (paper's choice: q=0.005, i.e. the 0.5/99.5 percentiles)");
    }
    println!("\n== Ablation A3: Outstanding-sparse alpha (inverted scaling) ==");
    if let Some(al) = j.req("outstanding_alpha")?.as_obj() {
        for (a, row) in al {
            println!(
                "  alpha={a:<5} range expansion {:.2}x   error@2:4 {:.4}",
                row.req("range_expansion")?.as_f64().unwrap(),
                row.req("output_error")?.as_f64().unwrap()
            );
        }
        println!("  (paper's choice: alpha=0.10 — expand range, keep error low)");
    }
    Ok(())
}

/// Coverage: fraction of linear FLOPs accelerated under the paper's skip
/// policy (the ">55%" headline), plus the ideal Amdahl speedup per ratio.
pub fn coverage(ctx: &ReproCtx) -> Result<()> {
    let manifest = Manifest::load(ctx.artifacts)?;
    let mut t = Table::new(
        "Coverage: % of linear computation accelerated (paper: >55%)",
        &["model", "skip layers", "coverage", "ideal 2:4", "ideal 4:8",
          "ideal 8:16"],
    );
    for model in models(ctx, &manifest) {
        let info = manifest.models.get(&model).unwrap();
        let g = Geometry::from_config(&info.config);
        let j = load_stats(ctx, &format!("sensitivity_{model}.json"))?;
        let skips: Vec<usize> = j
            .req("skip_layers")?
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let cov = g.coverage(&skips);
        t.row(vec![
            model.clone(),
            format!("{skips:?}"),
            format!("{:.1}%", cov * 100.0),
            format!("{:.2}x", g.ideal_linear_speedup(&skips, 2, 4)),
            format!("{:.2}x", g.ideal_linear_speedup(&skips, 4, 8)),
            format!("{:.2}x", g.ideal_linear_speedup(&skips, 8, 16)),
        ]);
    }
    t.print();
    Ok(())
}
