//! Readers for the python-emitted binary formats (see params_io.py):
//! `.atw` weights files and `.aev` eval datasets.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{DType, HostTensor};

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_i64(r: &mut impl Read) -> Result<i64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(i64::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

/// Load an `.atw` weights file; tensor order == executable argument order.
pub fn read_weights(path: &Path) -> Result<Vec<HostTensor>> {
    let f = File::open(path)
        .with_context(|| format!("open weights {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"ATWB" {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("{}: unsupported version {version}", path.display());
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let dtype = DType::from_code(read_u8(&mut r)?)?;
        let ndim = read_u8(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_i64(&mut r)?);
        }
        let nbytes = read_u64(&mut r)? as usize;
        let expect = dims.iter().product::<i64>() as usize * dtype.size();
        if nbytes != expect {
            bail!("tensor byte length {nbytes} != expected {expect}");
        }
        let mut data = vec![0u8; nbytes];
        r.read_exact(&mut data)?;
        out.push(HostTensor {
            name: String::from_utf8(name)?,
            dtype,
            dims,
            data,
        });
    }
    Ok(out)
}

/// One row of a multiple-choice eval set.
#[derive(Debug, Clone)]
pub struct McRow {
    /// sample the row belongs to
    pub sample: u32,
    /// choice index within the sample
    pub choice: u16,
    /// first token position of the scored span
    pub score_start: u16,
    /// scored span length, tokens
    pub score_len: u16,
    /// the sample's correct choice index
    pub gold: u16,
}

/// One row of a generation eval set.
#[derive(Debug, Clone)]
pub struct GenRow {
    /// sample the row belongs to
    pub sample: u32,
    /// prompt length, tokens
    pub prompt_len: u16,
    /// reference continuation to exact-match against
    pub gold: Vec<i32>,
    /// generation budget
    pub max_gen: u16,
}

/// Row table of an eval set (task kind decides the variant).
#[derive(Debug)]
pub enum EvalRows {
    /// multiple-choice rows
    Mc(Vec<McRow>),
    /// generation rows
    Gen(Vec<GenRow>),
}

/// A loaded `.aev` dataset: `tokens` is [n_rows, seq_len] row-major.
#[derive(Debug)]
pub struct EvalSet {
    /// padded row length, tokens
    pub seq_len: usize,
    /// distinct samples
    pub n_samples: usize,
    /// choices per sample (MC sets; 0 otherwise)
    pub n_choices: usize,
    /// `[n_rows, seq_len]` token matrix, row-major
    pub tokens: Vec<i32>,
    /// per-row metadata
    pub rows: EvalRows,
}

impl EvalSet {
    /// Total rows in the token matrix.
    pub fn n_rows(&self) -> usize {
        match &self.rows {
            EvalRows::Mc(r) => r.len(),
            EvalRows::Gen(r) => r.len(),
        }
    }

    /// Token row `i`.
    pub fn row_tokens(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// Read an `.aev` eval dataset from disk.
pub fn read_eval(path: &Path) -> Result<EvalSet> {
    let f = File::open(path)
        .with_context(|| format!("open eval {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"AEVD" {
        bail!("{}: bad magic", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("unsupported eval version {version}");
    }
    let kind = read_u8(&mut r)?;
    let seq_len = read_u32(&mut r)? as usize;
    let n_rows = read_u32(&mut r)? as usize;
    let n_samples = read_u32(&mut r)? as usize;
    let n_choices = read_u32(&mut r)? as usize;
    let mut tok_bytes = vec![0u8; 4 * seq_len * n_rows];
    r.read_exact(&mut tok_bytes)?;
    let tokens: Vec<i32> = tok_bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let rows = if kind == 0 {
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            rows.push(McRow {
                sample: read_u32(&mut r)?,
                choice: read_u16(&mut r)?,
                score_start: read_u16(&mut r)?,
                score_len: read_u16(&mut r)?,
                gold: read_u16(&mut r)?,
            });
        }
        EvalRows::Mc(rows)
    } else {
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            let sample = read_u32(&mut r)?;
            let prompt_len = read_u16(&mut r)?;
            let gold_len = read_u16(&mut r)? as usize;
            let mut gold_all = [0i32; 8];
            for g in gold_all.iter_mut() {
                *g = {
                    let mut b = [0u8; 4];
                    r.read_exact(&mut b)?;
                    i32::from_le_bytes(b)
                };
            }
            let max_gen = read_u16(&mut r)?;
            rows.push(GenRow {
                sample,
                prompt_len,
                gold: gold_all[..gold_len].to_vec(),
                max_gen,
            });
        }
        EvalRows::Gen(rows)
    };
    Ok(EvalSet { seq_len, n_samples, n_choices, tokens, rows })
}
