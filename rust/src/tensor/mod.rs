//! Host-side tensors: dtype-tagged buffers, the .atw/.aev binary formats,
//! and the small numeric helpers the eval path needs (log-softmax etc.).

pub mod io;
pub mod math;

use anyhow::{bail, Result};

/// Element type of a [`HostTensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float
    F32,
    /// 32-bit signed integer
    I32,
    /// 8-bit signed integer
    I8,
    /// 8-bit unsigned integer
    U8,
}

impl DType {
    /// Decode the `.atw` on-disk dtype code.
    pub fn from_code(c: u8) -> Result<DType> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::I8,
            3 => DType::U8,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

/// A named host tensor (row-major, little-endian raw bytes).
#[derive(Debug, Clone)]
pub struct HostTensor {
    /// tensor name
    pub name: String,
    /// element type
    pub dtype: DType,
    /// shape
    pub dims: Vec<i64>,
    /// raw little-endian bytes, row-major
    pub data: Vec<u8>,
}

impl HostTensor {
    /// An f32 tensor from values (panics on shape mismatch).
    pub fn f32(name: &str, dims: Vec<i64>, vals: &[f32]) -> HostTensor {
        assert_eq!(vals.len() as i64, dims.iter().product::<i64>());
        HostTensor {
            name: name.to_string(),
            dtype: DType::F32,
            dims,
            data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    /// An i32 tensor from values (panics on shape mismatch).
    pub fn i32(name: &str, dims: Vec<i64>, vals: &[i32]) -> HostTensor {
        assert_eq!(vals.len() as i64, dims.iter().product::<i64>());
        HostTensor {
            name: name.to_string(),
            dtype: DType::I32,
            dims,
            data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    /// Element count (product of dims).
    pub fn n_elems(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// Decode as f32 values (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode as i32 values (errors on dtype mismatch).
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("{}: not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Convert to a PJRT literal (PJRT backend only).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let ty = match self.dtype {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::I8 => xla::ElementType::S8,
            DType::U8 => xla::ElementType::U8,
        };
        let dims: Vec<usize> = self.dims.iter().map(|&d| d as usize).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty, &dims, &self.data,
        )?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = HostTensor::f32("x", vec![2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.n_elems(), 4);
        assert!(t.as_i32().is_err());
    }
}
