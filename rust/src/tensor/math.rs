//! Host-side numeric helpers for the eval path (log-softmax scoring,
//! greedy argmax) — computed on logits copied back from PJRT.

/// Log-softmax over the last axis of a [rows, v] matrix, evaluated lazily:
/// returns log p(target) for one position without materializing the whole
/// distribution.
pub fn token_logprob(logits_row: &[f32], target: usize) -> f64 {
    let mx = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut denom = 0.0f64;
    for &v in logits_row {
        denom += ((v - mx) as f64).exp();
    }
    (logits_row[target] - mx) as f64 - denom.ln()
}

/// Index of the largest value (first wins on ties; 0 on empty input).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Sum of log-probs of `targets[i]` read from rows `start..start+len` of a
/// [seq, vocab] logits matrix, with the usual next-token shift: the logits
/// at position p-1 predict token at position p.
pub fn span_logprob(
    logits: &[f32],
    vocab: usize,
    span_start: usize,
    targets: &[i32],
) -> f64 {
    let mut acc = 0.0;
    for (i, &t) in targets.iter().enumerate() {
        let pos = span_start + i - 1; // predicting token at span_start + i
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        acc += token_logprob(row, t as usize);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprob_normalizes() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|t| token_logprob(&row, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // higher logit -> higher logprob
        assert!(token_logprob(&row, 2) > token_logprob(&row, 0));
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
    }

    #[test]
    fn span_shift() {
        // vocab=2, seq=3. logits[0] strongly predicts token 1,
        // logits[1] strongly predicts token 0.
        let logits = vec![
            -10.0, 10.0, // pos 0
            10.0, -10.0, // pos 1
            0.0, 0.0, // pos 2
        ];
        // span starting at position 1, targets [1, 0]: uses rows 0 and 1
        let lp = span_logprob(&logits, 2, 1, &[1, 0]);
        assert!(lp > -1e-6, "lp={lp}");
    }
}
