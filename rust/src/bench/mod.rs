//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs `[[bench]]` targets with `harness = false`; each
//! target uses this module: warmup, N timed iterations, outlier-robust
//! summary (median + MAD), and machine-readable one-line results that
//! EXPERIMENTS.md quotes.

use std::time::Instant;

use crate::metrics::stats::Histogram;

/// Summary of one benchmark run.
pub struct BenchResult {
    /// benchmark name
    pub name: String,
    /// timed iterations
    pub iters: usize,
    /// median iteration seconds
    pub median_secs: f64,
    /// mean iteration seconds
    pub mean_secs: f64,
    /// 95th-percentile iteration seconds
    pub p95_secs: f64,
    /// elements/second from the median, when an element count was given
    pub throughput: Option<f64>,
}

impl BenchResult {
    /// One-line machine-readable report.
    pub fn report(&self) -> String {
        let tp = self
            .throughput
            .map(|t| format!("  {:>12.1} elem/s", t))
            .unwrap_or_default();
        format!(
            "bench {:<44} median {:>10.3}ms  mean {:>10.3}ms  p95 {:>10.3}ms  (n={}){}",
            self.name,
            self.median_secs * 1e3,
            self.mean_secs * 1e3,
            self.p95_secs * 1e3,
            self.iters,
            tp
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. `elems` (optional)
/// computes element throughput from the median.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    elems: Option<u64>,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        h.observe(t0.elapsed().as_secs_f64());
    }
    let s = h.summary();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_secs: s.p50,
        mean_secs: s.mean,
        p95_secs: s.p95,
        throughput: elems.map(|e| e as f64 / s.p50.max(1e-12)),
    };
    println!("{}", r.report());
    r
}

/// Black-box to stop the optimizer from eliding benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
