//! Streaming statistics: reservoir-free exact histogram (we keep all
//! samples — serving runs here are small) with percentile queries, plus a
//! criterion-style summary (mean/median/stddev) for the bench harness.

#[derive(Default, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn observe(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        // nearest-rank with linear interpolation
        let x = p / 100.0 * (self.samples.len() - 1) as f64;
        let lo = x.floor() as usize;
        let hi = x.ceil() as usize;
        let frac = x - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn summary(&mut self) -> Summary {
        if self.samples.is_empty() {
            return Summary::default();
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let mean = self.samples.iter().sum::<f64>() / n as f64;
        let var = self
            .samples
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f64>()
            / n.max(1) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: self.samples[0],
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            max: *self.samples.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert!((h.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((h.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((h.percentile(100.0) - 100.0).abs() < 1e-9);
        let s = h.summary();
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.summary().n, 0);
    }
}
