//! Streaming statistics: reservoir-free exact histogram (we keep all
//! samples — serving runs here are small) with percentile queries, plus a
//! criterion-style summary (mean/median/stddev) for the bench harness.

/// Exact sample histogram with percentile queries (module docs).
#[derive(Default, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

/// Point statistics of a [`Histogram`].
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    /// sample count
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// population standard deviation
    pub std: f64,
    /// smallest sample
    pub min: f64,
    /// median
    pub p50: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
    /// largest sample
    pub max: f64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample.
    pub fn observe(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Samples recorded so far.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// The `p`-th percentile (0–100), nearest-rank with linear
    /// interpolation; 0.0 on an empty histogram.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        // nearest-rank with linear interpolation
        let x = p / 100.0 * (self.samples.len() - 1) as f64;
        let lo = x.floor() as usize;
        let hi = x.ceil() as usize;
        let frac = x - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    /// Full point-statistics summary (zeroed on an empty histogram).
    pub fn summary(&mut self) -> Summary {
        if self.samples.is_empty() {
            return Summary::default();
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let mean = self.samples.iter().sum::<f64>() / n as f64;
        let var = self
            .samples
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f64>()
            / n.max(1) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: self.samples[0],
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            max: *self.samples.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert!((h.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((h.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((h.percentile(100.0) - 100.0).abs() < 1e-9);
        let s = h.summary();
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.summary().n, 0);
    }
}
