//! Serving metrics: counters, latency histograms, TTFT/TPOT summaries
//! (criterion-style statistics without criterion).

pub mod stats;

pub use stats::{Histogram, Summary};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Engine-level metrics, shared across coordinator threads.
#[derive(Default)]
pub struct EngineMetrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub prefill_batches: AtomicU64,
    pub decode_batches: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_tokens: AtomicU64,
    pub padded_prefill_tokens: AtomicU64,
    pub ttft: Mutex<Histogram>,
    pub tpot: Mutex<Histogram>,
    pub e2e: Mutex<Histogram>,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn observe_ttft(&self, secs: f64) {
        self.ttft.lock().unwrap().observe(secs);
    }

    pub fn observe_tpot(&self, secs: f64) {
        self.tpot.lock().unwrap().observe(secs);
    }

    pub fn observe_e2e(&self, secs: f64) {
        self.e2e.lock().unwrap().observe(secs);
    }

    pub fn report(&self, wall_secs: f64) -> String {
        let done = self.requests_completed.load(Ordering::Relaxed);
        let ptok = self.prefill_tokens.load(Ordering::Relaxed);
        let dtok = self.decode_tokens.load(Ordering::Relaxed);
        let pad = self.padded_prefill_tokens.load(Ordering::Relaxed);
        let ttft = self.ttft.lock().unwrap().summary();
        let tpot = self.tpot.lock().unwrap().summary();
        let e2e = self.e2e.lock().unwrap().summary();
        format!(
            "requests={done} ({:.1} req/s)  prefill_tok={ptok} \
             decode_tok={dtok} pad_frac={:.2}\n\
             TTFT  p50={:.1}ms p95={:.1}ms p99={:.1}ms\n\
             TPOT  p50={:.1}ms p95={:.1}ms\n\
             E2E   p50={:.1}ms p95={:.1}ms  tok_throughput={:.0} tok/s",
            done as f64 / wall_secs.max(1e-9),
            if ptok + pad > 0 {
                pad as f64 / (ptok + pad) as f64
            } else {
                0.0
            },
            ttft.p50 * 1e3,
            ttft.p95 * 1e3,
            ttft.p99 * 1e3,
            tpot.p50 * 1e3,
            tpot.p95 * 1e3,
            e2e.p50 * 1e3,
            e2e.p95 * 1e3,
            (ptok + dtok) as f64 / wall_secs.max(1e-9),
        )
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}
