//! Line-delimited JSON TCP front-end (std::net, thread-per-connection).
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": [1, 84, 91], "max_new_tokens": 8,
//!       "sparsity": "8:16:ls", "deadline_ticks": 50}
//!   <- {"id": 1, "tokens": [93, 2], "ttft_ms": 3.1, "e2e_ms": 9.0}
//!   <- {"id": 1, "tokens": [], ..., "error": "...", "kind":
//!      "rejected"}   (failed requests; `kind` in
//!      transient|fatal|rejected)
//!   -> {"cmd": "stats"}            <- {"requests": ...}
//!   -> {"cmd": "quit"}             (closes the connection)
//!   -> {"cmd": "shutdown"}         <- {"ok": "draining"}  (graceful
//!      drain of the engine/pool behind the gateway, then the server
//!      exits; see `main::serve`)
//!
//! The front door is a [`Gateway`]: one engine channel (the classic
//! single-replica deployment) or a supervised replica pool — the wire
//! protocol is identical either way.
//!
//! The front-end is hardened against hostile or broken clients: input
//! lines are bounded at [`MAX_LINE_BYTES`] (oversized lines are
//! answered with a structured error and the stream resyncs at the next
//! newline), malformed JSON fails the *line* with an error reply — not
//! the connection, and a connection's IO error kills only its own
//! thread — the acceptor and every other connection keep serving.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;

use anyhow::{Context, Result};

use crate::coordinator::replica::Gateway;
use crate::coordinator::request::{Request, Response, SparsityConfig};
use crate::metrics::EngineMetrics;
use crate::util::json::{self, Json};

/// Upper bound on one protocol line. A line past the cap is rejected
/// with a structured error and the stream resyncs at the next newline;
/// memory per connection stays bounded no matter what the peer sends.
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// Parse one request line of the wire protocol (module docs) into a
/// coordinator [`Request`].
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    let id = j.req_usize("id")? as u64;
    let prompt: Vec<i32> = j
        .req("prompt")?
        .as_arr()
        .context("prompt not an array")?
        .iter()
        .filter_map(|v| v.as_f64())
        .map(|v| v as i32)
        .collect();
    if prompt.is_empty() {
        anyhow::bail!("prompt must be a non-empty token array");
    }
    let max_new = j.req_usize("max_new_tokens").unwrap_or(8);
    let cfg = j
        .get("sparsity")
        .and_then(|s| s.as_str())
        .map(|s| SparsityConfig::parse(s))
        .unwrap_or(Some(SparsityConfig::dense()))
        .context("bad sparsity config")?;
    let deadline_ticks = j
        .get("deadline_ticks")
        .and_then(|v| v.as_f64())
        .map(|v| v.max(0.0) as u64)
        .unwrap_or(0);
    Ok(Request {
        id,
        prompt,
        max_new_tokens: max_new,
        config: cfg,
        deadline_ticks,
    })
}

/// Serialize a coordinator [`Response`] as one wire-protocol line.
/// Failed requests carry `error` (the reason) and `kind`
/// (`transient|fatal|rejected`) alongside any partial tokens.
pub fn response_json(r: &Response) -> String {
    let mut pairs = vec![
        ("id", json::num(r.id as f64)),
        (
            "tokens",
            Json::Arr(r.tokens.iter().map(|t| json::num(*t as f64)).collect()),
        ),
        ("ttft_ms", json::num(r.ttft_secs * 1e3)),
        ("e2e_ms", json::num(r.e2e_secs * 1e3)),
    ];
    if let Some(err) = &r.error {
        pairs.push(("error", json::s(&err.reason)));
        pairs.push(("kind", json::s(err.kind.label())));
    }
    json::obj(pairs).to_string()
}

/// One wire-protocol error line (same shape as a failed [`Response`]'s
/// error fields, minus the request echo).
fn error_json(kind: &str, msg: &str) -> String {
    json::obj(vec![("error", json::s(msg)), ("kind", json::s(kind))])
        .to_string()
}

fn handle_conn(
    stream: TcpStream,
    gateway: Gateway,
    metrics: Arc<EngineMetrics>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // bounded read: at most MAX_LINE_BYTES + 1, so a missing
        // newline can never grow the buffer without limit
        let n = match (&mut reader)
            .take((MAX_LINE_BYTES + 1) as u64)
            .read_until(b'\n', &mut buf)
        {
            Ok(0) => break, // EOF: client closed cleanly
            Ok(n) => n,
            Err(e) => {
                // this connection is broken; the listener survives
                log::trace(&format!("connection {peer} read error: {e}"));
                break;
            }
        };
        if n > MAX_LINE_BYTES {
            // discard the rest of the jumbo line, then answer and
            // resync at the next newline
            while buf.last() != Some(&b'\n') {
                buf.clear();
                match (&mut reader)
                    .take(MAX_LINE_BYTES as u64)
                    .read_until(b'\n', &mut buf)
                {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
            writeln!(
                writer,
                "{}",
                error_json(
                    "rejected",
                    &format!("line exceeds {MAX_LINE_BYTES} bytes"),
                )
            )?;
            continue;
        }
        let text = String::from_utf8_lossy(&buf);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        // malformed JSON fails this LINE, never the connection
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    error_json("rejected", &format!("malformed JSON: {e}"))
                )?;
                continue;
            }
        };
        if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
            match cmd {
                "quit" => break,
                "stats" => {
                    writeln!(writer, "{}", stats_json(&metrics))?;
                    continue;
                }
                "shutdown" => {
                    // graceful drain: stop admitting, finish what is
                    // in flight, then the serve loop in `main` exits
                    gateway.begin_shutdown();
                    writeln!(
                        writer,
                        "{}",
                        json::obj(vec![("ok", json::s("draining"))])
                    )?;
                    break;
                }
                other => {
                    writeln!(
                        writer,
                        "{}",
                        error_json(
                            "rejected",
                            &format!("unknown cmd {other}"),
                        )
                    )?;
                    continue;
                }
            }
        }
        match parse_request(line) {
            Ok(req) => {
                let (tx, rx) = channel();
                if gateway.submit(req, tx).is_err() {
                    writeln!(
                        writer,
                        "{}",
                        error_json("fatal", "engine unavailable")
                    )?;
                    break;
                }
                // synchronous per-connection semantics: wait for this
                // request (pipelining across connections, not within
                // one). A dropped reply (engine fault path) answers
                // the client rather than hanging it.
                match rx.recv() {
                    Ok(resp) => {
                        writeln!(writer, "{}", response_json(&resp))?
                    }
                    Err(_) => {
                        writeln!(
                            writer,
                            "{}",
                            error_json(
                                "fatal",
                                "engine dropped the request",
                            )
                        )?;
                    }
                }
            }
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    error_json("rejected", &e.to_string())
                )?;
            }
        }
    }
    log::trace(&format!("connection {peer} closed"));
    Ok(())
}

mod log {
    pub fn trace(_s: &str) {}
}

fn stats_json(m: &EngineMetrics) -> String {
    use std::sync::atomic::Ordering;
    json::obj(vec![
        (
            "requests_completed",
            json::num(m.requests_completed.load(Ordering::Relaxed) as f64),
        ),
        (
            "prefill_batches",
            json::num(m.prefill_batches.load(Ordering::Relaxed) as f64),
        ),
        (
            "decode_batches",
            json::num(m.decode_batches.load(Ordering::Relaxed) as f64),
        ),
    ])
    .to_string()
}

/// Serve until the process is killed. Returns the bound address (useful
/// with port 0 in tests).
pub fn serve(
    addr: &str,
    gateway: Gateway,
    metrics: Arc<EngineMetrics>,
) -> Result<(std::net::SocketAddr, thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    let bound = listener.local_addr()?;
    let handle = thread::Builder::new()
        .name("tcp-acceptor".into())
        .spawn(move || {
            for stream in listener.incoming() {
                match stream {
                    Ok(s) => {
                        let gw = gateway.clone();
                        let m = Arc::clone(&metrics);
                        thread::spawn(move || {
                            let _ = handle_conn(s, gw, m);
                        });
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok((bound, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::error::{ErrorKind, RequestError};
    use crate::coordinator::scheduler::EngineMsg;

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 3, "prompt": [1, 2, 3], "max_new_tokens": 5,
                "sparsity": "4:8:ls", "deadline_ticks": 40}"#,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.config.nm, Some((4, 8)));
        assert_eq!(r.deadline_ticks, 40);
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"id": 1, "prompt": [1]}"#).unwrap();
        assert!(r.config.nm.is_none());
        assert_eq!(r.max_new_tokens, 8);
        assert_eq!(r.deadline_ticks, 0, "no deadline by default");
    }

    #[test]
    fn parse_request_rejects_empty_prompt() {
        let e = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap_err();
        assert!(e.to_string().contains("non-empty"));
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            id: 9,
            tokens: vec![5, 2],
            ttft_secs: 0.001,
            e2e_secs: 0.002,
            prefill_artifact: String::new(),
            error: None,
        };
        let j = Json::parse(&response_json(&r)).unwrap();
        assert_eq!(j.req_usize("id").unwrap(), 9);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("error").is_none(), "success carries no error");
    }

    #[test]
    fn response_json_carries_error_fields() {
        let r = Response {
            id: 4,
            tokens: vec![1],
            ttft_secs: 0.0,
            e2e_secs: 0.0,
            prefill_artifact: String::new(),
            error: Some(RequestError {
                kind: ErrorKind::Rejected,
                reason: "overloaded".into(),
            }),
        };
        let j = Json::parse(&response_json(&r)).unwrap();
        assert_eq!(
            j.get("kind").and_then(|k| k.as_str()),
            Some("rejected")
        );
        assert_eq!(
            j.get("error").and_then(|e| e.as_str()),
            Some("overloaded")
        );
        assert_eq!(
            j.req("tokens").unwrap().as_arr().unwrap().len(),
            1,
            "partial tokens ride along"
        );
    }

    /// A stand-in engine thread answering every submit with a canned
    /// two-token success, wrapped as a single-engine [`Gateway`].
    fn fake_engine() -> Gateway {
        let (tx, rx) = channel::<EngineMsg>();
        thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                if let EngineMsg::Submit(req, reply) = msg {
                    let _ = reply.send(Response {
                        id: req.id,
                        tokens: vec![7, 2],
                        ttft_secs: 0.0,
                        e2e_secs: 0.0,
                        prefill_artifact: String::new(),
                        error: None,
                    });
                }
            }
        });
        Gateway::Direct(tx)
    }

    #[test]
    fn shutdown_cmd_acknowledges_and_closes() {
        let (addr, _h) = serve(
            "127.0.0.1:0",
            fake_engine(),
            Arc::new(EngineMetrics::new()),
        )
        .unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.get("ok").and_then(|v| v.as_str()),
            Some("draining"),
            "shutdown is acknowledged before the connection closes"
        );
        line.clear();
        assert_eq!(
            r.read_line(&mut line).unwrap(),
            0,
            "the issuing connection is closed after the ack"
        );
    }

    #[test]
    fn malformed_lines_do_not_kill_the_connection() {
        let (addr, _h) = serve(
            "127.0.0.1:0",
            fake_engine(),
            Arc::new(EngineMetrics::new()),
        )
        .unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "this is not json").unwrap();
        writeln!(s, r#"{{"id": 1, "prompt": [1, 2]}}"#).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.get("kind").and_then(|k| k.as_str()),
            Some("rejected"),
            "malformed line answers a structured error"
        );
        line.clear();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.req_usize("id").unwrap(),
            1,
            "the connection survives and serves the next request"
        );
    }

    #[test]
    fn oversized_lines_reject_then_resync() {
        let (addr, _h) = serve(
            "127.0.0.1:0",
            fake_engine(),
            Arc::new(EngineMetrics::new()),
        )
        .unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        let jumbo = vec![b'x'; MAX_LINE_BYTES + 64];
        s.write_all(&jumbo).unwrap();
        s.write_all(b"\n").unwrap();
        writeln!(s, r#"{{"id": 2, "prompt": [3]}}"#).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.get("kind").and_then(|k| k.as_str()),
            Some("rejected")
        );
        assert!(j
            .get("error")
            .and_then(|e| e.as_str())
            .is_some_and(|e| e.contains("exceeds")));
        line.clear();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.req_usize("id").unwrap(),
            2,
            "the stream resyncs at the newline"
        );
    }
}
