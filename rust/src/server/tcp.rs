//! Line-delimited JSON TCP front-end (std::net, thread-per-connection).
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": [1, 84, 91], "max_new_tokens": 8,
//!       "sparsity": "8:16:ls"}
//!   <- {"id": 1, "tokens": [93, 2], "ttft_ms": 3.1, "e2e_ms": 9.0}
//!   -> {"cmd": "stats"}            <- {"requests": ...}
//!   -> {"cmd": "quit"}             (closes the connection)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread;

use anyhow::{Context, Result};

use crate::coordinator::request::{Request, Response, SparsityConfig};
use crate::coordinator::scheduler::EngineMsg;
use crate::metrics::EngineMetrics;
use crate::util::json::{self, Json};

/// Parse one request line of the wire protocol (module docs) into a
/// coordinator [`Request`].
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    let id = j.req_usize("id")? as u64;
    let prompt: Vec<i32> = j
        .req("prompt")?
        .as_arr()
        .context("prompt not an array")?
        .iter()
        .filter_map(|v| v.as_f64())
        .map(|v| v as i32)
        .collect();
    if prompt.is_empty() {
        anyhow::bail!("prompt must be a non-empty token array");
    }
    let max_new = j.req_usize("max_new_tokens").unwrap_or(8);
    let cfg = j
        .get("sparsity")
        .and_then(|s| s.as_str())
        .map(|s| SparsityConfig::parse(s))
        .unwrap_or(Some(SparsityConfig::dense()))
        .context("bad sparsity config")?;
    Ok(Request { id, prompt, max_new_tokens: max_new, config: cfg })
}

/// Serialize a coordinator [`Response`] as one wire-protocol line.
pub fn response_json(r: &Response) -> String {
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        (
            "tokens",
            Json::Arr(r.tokens.iter().map(|t| json::num(*t as f64)).collect()),
        ),
        ("ttft_ms", json::num(r.ttft_secs * 1e3)),
        ("e2e_ms", json::num(r.e2e_secs * 1e3)),
    ])
    .to_string()
}

fn handle_conn(
    stream: TcpStream,
    engine_tx: Sender<EngineMsg>,
    metrics: Arc<EngineMetrics>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)?;
        if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
            match cmd {
                "quit" => break,
                "stats" => {
                    writeln!(writer, "{}", stats_json(&metrics))?;
                    continue;
                }
                other => {
                    writeln!(
                        writer,
                        "{{\"error\":\"unknown cmd {other}\"}}"
                    )?;
                    continue;
                }
            }
        }
        match parse_request(&line) {
            Ok(req) => {
                let (tx, rx) = channel();
                engine_tx
                    .send(EngineMsg::Submit(req, tx))
                    .map_err(|_| anyhow::anyhow!("engine gone"))?;
                // synchronous per-connection semantics: wait for this
                // request (pipelining across connections, not within one)
                let resp = rx.recv()?;
                writeln!(writer, "{}", response_json(&resp))?;
            }
            Err(e) => {
                writeln!(writer, "{{\"error\":{:?}}}", e.to_string())?;
            }
        }
    }
    log::trace(&format!("connection {peer} closed"));
    Ok(())
}

mod log {
    pub fn trace(_s: &str) {}
}

fn stats_json(m: &EngineMetrics) -> String {
    use std::sync::atomic::Ordering;
    json::obj(vec![
        (
            "requests_completed",
            json::num(m.requests_completed.load(Ordering::Relaxed) as f64),
        ),
        (
            "prefill_batches",
            json::num(m.prefill_batches.load(Ordering::Relaxed) as f64),
        ),
        (
            "decode_batches",
            json::num(m.decode_batches.load(Ordering::Relaxed) as f64),
        ),
    ])
    .to_string()
}

/// Serve until the process is killed. Returns the bound address (useful
/// with port 0 in tests).
pub fn serve(
    addr: &str,
    engine_tx: Sender<EngineMsg>,
    metrics: Arc<EngineMetrics>,
) -> Result<(std::net::SocketAddr, thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    let bound = listener.local_addr()?;
    let handle = thread::Builder::new()
        .name("tcp-acceptor".into())
        .spawn(move || {
            for stream in listener.incoming() {
                match stream {
                    Ok(s) => {
                        let tx = engine_tx.clone();
                        let m = Arc::clone(&metrics);
                        thread::spawn(move || {
                            let _ = handle_conn(s, tx, m);
                        });
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok((bound, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 3, "prompt": [1, 2, 3], "max_new_tokens": 5,
                "sparsity": "4:8:ls"}"#,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.config.nm, Some((4, 8)));
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"id": 1, "prompt": [1]}"#).unwrap();
        assert!(r.config.nm.is_none());
        assert_eq!(r.max_new_tokens, 8);
    }

    #[test]
    fn parse_request_rejects_empty_prompt() {
        let e = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap_err();
        assert!(e.to_string().contains("non-empty"));
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            id: 9,
            tokens: vec![5, 2],
            ttft_secs: 0.001,
            e2e_secs: 0.002,
            prefill_artifact: String::new(),
        };
        let j = Json::parse(&response_json(&r)).unwrap();
        assert_eq!(j.req_usize("id").unwrap(), 9);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}
