//! Serving front-ends and workload generation.
//!
//! * `tcp`      — a line-delimited JSON protocol over std::net (no tokio):
//!                request  {"id":1,"prompt":[...],"max_new_tokens":8,
//!                          "sparsity":"8:16:ls"}
//!                response {"id":1,"tokens":[...],"ttft_ms":..,"e2e_ms":..}
//! * `workload` — deterministic client simulations: poisson arrivals,
//!                prompt-length mixes, per-request sparsity mixes, and
//!                trace replay for the serving benches.

pub mod config;
pub mod tcp;
pub mod workload;
