//! Workload generation for the serving benches: synthetic prompts drawn
//! from the same token world the models were trained on, poisson or burst
//! arrivals, and per-request sparsity-config mixes.

use crate::coordinator::request::{Request, SparsityConfig};
use crate::util::rng::Rng;

/// Token-vocabulary constants mirrored from python/compile/tokenizer.py.
pub mod vocab {
    /// beginning-of-sequence
    pub const BOS: i32 = 1;
    /// end-of-sequence
    pub const EOS: i32 = 2;
    /// fact-query marker
    pub const QRY: i32 = 4;
    /// answer marker
    pub const ANS: i32 = 5;
    /// first digit token (0–9 follow)
    pub const DIGIT0: i32 = 10;
    /// first relation token
    pub const REL0: i32 = 32;
    /// first entity token
    pub const ENT0: i32 = 48;
    /// first grammar-word token
    pub const WORD_A0: i32 = 80;
    /// grammar-word vocabulary size
    pub const N_WORDS_A: i32 = 128;
    /// first key token of the kv-pair sublanguage
    pub const KEY0: i32 = 336;
    /// key vocabulary size
    pub const N_KEYS: i32 = 48;
}

/// Shape of a synthetic serving workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// requests to generate
    pub n_requests: usize,
    /// mean requests/second for poisson arrivals (0 = all at once)
    pub rate: f64,
    /// shortest prompt, tokens
    pub prompt_len_lo: usize,
    /// longest prompt, tokens
    pub prompt_len_hi: usize,
    /// generation budget per request
    pub max_new_tokens: usize,
    /// sparsity mix: (config, weight)
    pub mix: Vec<(SparsityConfig, f64)>,
    /// RNG seed (same spec -> same workload)
    pub seed: u64,
    /// multi-tenant shared prefixes: requests are assigned round-robin
    /// to this many tenants, each with a fixed prompt prefix (0 or 1 =
    /// every prompt independent, the default)
    pub tenants: usize,
    /// tokens of shared per-tenant prompt prefix (counts toward the
    /// prompt length; block-align it to the engine's `kv_block` to make
    /// every shared token prefix-cacheable)
    pub tenant_prefix_len: usize,
    /// Pareto shape for heavy-tail prompt lengths (0 = uniform lengths,
    /// the default). When set, lengths cluster near `prompt_len_lo`
    /// with a long tail reaching `prompt_len_hi` — the mix that makes
    /// chunked prefill earn its keep.
    pub tail_alpha: f64,
    /// upper generation budget: when > `max_new_tokens`, each request
    /// draws its budget uniformly from
    /// `max_new_tokens ..= max_new_tokens_hi` (0 = every request uses
    /// `max_new_tokens`, the default)
    pub max_new_tokens_hi: usize,
    /// burst arrivals: requests land in same-instant groups of this
    /// size (a `rate` gap separates groups when set; 0 or 1 = no
    /// bursting, the default). The overload workload: a burst's worth
    /// of prompt tokens hits admission at once.
    pub burst_size: usize,
    /// tick deadline given to a `deadline_frac` share of requests
    /// (0 = no deadlines, the default)
    pub deadline_ticks: u64,
    /// fraction of requests carrying `deadline_ticks` (the rest run
    /// without a deadline); only drawn when `deadline_ticks > 0`, so
    /// specs predating the knob keep their exact request streams
    pub deadline_frac: f64,
}

impl WorkloadSpec {
    /// `n` all-dense requests with 12–48-token prompts, no arrival gaps.
    pub fn uniform_dense(n: usize) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: n,
            rate: 0.0,
            prompt_len_lo: 12,
            prompt_len_hi: 48,
            max_new_tokens: 8,
            mix: vec![(SparsityConfig::dense(), 1.0)],
            seed: 7,
            tenants: 0,
            tenant_prefix_len: 0,
            tail_alpha: 0.0,
            max_new_tokens_hi: 0,
            burst_size: 0,
            deadline_ticks: 0,
            deadline_frac: 0.0,
        }
    }

    /// `n` requests with a heavy-tail length mix: most prompts near 8
    /// tokens, a Pareto(1.2) tail out to 64, generation budgets drawn
    /// from 1–8. Short requests keep arriving behind the occasional
    /// long prompt, so chunked prefill (vs head-of-line blocking) is
    /// actually observable.
    pub fn heavy_tail(n: usize) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: n,
            rate: 0.0,
            prompt_len_lo: 8,
            prompt_len_hi: 64,
            max_new_tokens: 1,
            max_new_tokens_hi: 8,
            mix: vec![(SparsityConfig::dense(), 1.0)],
            seed: 7,
            tenants: 0,
            tenant_prefix_len: 0,
            tail_alpha: 1.2,
            burst_size: 0,
            deadline_ticks: 0,
            deadline_frac: 0.0,
        }
    }

    /// `n` all-dense requests split across `tenants` tenants, each
    /// sharing a fixed `prefix_len`-token prompt prefix — the canonical
    /// prefix-cache workload (warm requests prefill only their suffix).
    pub fn shared_prefix(
        n: usize,
        tenants: usize,
        prefix_len: usize,
    ) -> WorkloadSpec {
        let mut spec = WorkloadSpec::uniform_dense(n);
        spec.prompt_len_lo = spec.prompt_len_lo.max(prefix_len + 4);
        spec.prompt_len_hi = spec.prompt_len_hi.max(prefix_len + 16);
        spec.tenants = tenants;
        spec.tenant_prefix_len = prefix_len;
        spec
    }

    /// `n` requests arriving in same-instant bursts of `burst`, half
    /// of them carrying a `deadline`-tick budget — the overload
    /// workload: a burst's worth of prompt tokens hits admission at
    /// once, driving the degrade/shed watermarks and deadline sweeps
    /// ([`crate::coordinator::scheduler::DegradePolicy`]).
    pub fn bursty_deadlines(
        n: usize,
        burst: usize,
        deadline: u64,
    ) -> WorkloadSpec {
        let mut spec = WorkloadSpec::uniform_dense(n);
        spec.burst_size = burst;
        spec.deadline_ticks = deadline;
        spec.deadline_frac = 0.5;
        spec
    }
}

/// Replica lifecycle actions a chaos schedule can fire against a
/// [`crate::coordinator::replica::ReplicaPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaAction {
    /// crash the replica (panic out of its serve loop; in-flight work
    /// fails over, the supervisor restarts the slot)
    Kill,
    /// gracefully drain the replica (queued work re-dispatches,
    /// in-flight work finishes in place, the slot goes `Down`)
    Drain,
    /// restart a previously killed/drained slot with a fresh bind
    Restart,
}

/// One scheduled replica lifecycle event in a chaos run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaEvent {
    /// fire time, milliseconds from workload start
    pub at_ms: u64,
    /// target replica slot
    pub replica: usize,
    /// what happens to it
    pub action: ReplicaAction,
}

/// Deterministic replica chaos schedule: `n_events` kill/drain/restart
/// events spread over `span_ms`, in fire order. Drawn from the seed's
/// own sub-rng, so the request streams of [`generate`] are untouched
/// by the presence (or size) of a chaos schedule. `Restart` only ever
/// targets a slot an earlier event took down.
pub fn replica_schedule(
    seed: u64,
    replicas: usize,
    n_events: usize,
    span_ms: u64,
) -> Vec<ReplicaEvent> {
    let mut rng = Rng::new(seed ^ 0x5e7a_c0de);
    let mut out = Vec::with_capacity(n_events);
    let mut downed: Vec<usize> = Vec::new();
    let step = span_ms / (n_events.max(1) as u64) + 1;
    let mut t = 0u64;
    for _ in 0..n_events {
        t += rng.below(step) + 1;
        let (replica, action) = match rng.below(4) {
            3 if !downed.is_empty() => {
                let i = downed.remove(rng.usize_below(downed.len()));
                (i, ReplicaAction::Restart)
            }
            2 => (rng.usize_below(replicas), ReplicaAction::Drain),
            _ => (rng.usize_below(replicas), ReplicaAction::Kill),
        };
        if action != ReplicaAction::Restart && !downed.contains(&replica)
        {
            downed.push(replica);
        }
        out.push(ReplicaEvent { at_ms: t, replica, action });
    }
    out
}

/// A generated request + its arrival offset (seconds from start).
pub struct TimedRequest {
    /// arrival time, seconds from workload start
    pub at: f64,
    /// the request itself
    pub req: Request,
}

/// Grammar-like synthetic prompt (plausible in-distribution tokens).
pub fn gen_prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    let mut p = vec![vocab::BOS];
    while p.len() < len.saturating_sub(4) {
        match rng.below(4) {
            0 => {
                // fact query
                p.extend([
                    vocab::QRY,
                    vocab::ENT0 + rng.below(32) as i32,
                    vocab::REL0 + rng.below(8) as i32,
                    vocab::ANS,
                ]);
            }
            1 => {
                // grammar words
                for _ in 0..rng.below(6) + 2 {
                    p.push(vocab::WORD_A0 + rng.below(128) as i32);
                }
                p.push(vocab::EOS);
            }
            2 => {
                // kv pairs
                for _ in 0..rng.below(4) + 1 {
                    p.push(vocab::KEY0 + rng.below(vocab::N_KEYS as u64) as i32);
                    p.push(vocab::DIGIT0 + rng.below(10) as i32);
                }
            }
            _ => {
                // arithmetic
                p.extend([
                    vocab::DIGIT0 + rng.below(10) as i32,
                    20, // PLUS
                    vocab::DIGIT0 + rng.below(10) as i32,
                    23, // EQ
                ]);
            }
        }
    }
    // fill to exactly `len` with grammar words
    while p.len() < len {
        p.push(vocab::WORD_A0 + rng.below(vocab::N_WORDS_A as u64) as i32);
    }
    p.truncate(len);
    p
}

/// Generate the spec's full request schedule, deterministically.
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = Rng::new(spec.seed);
    let total_w: f64 = spec.mix.iter().map(|(_, w)| w).sum();
    // fixed per-tenant prompt prefixes, each from its own sub-rng so the
    // per-request token stream below is untouched by the tenant count
    let tenanted = spec.tenants > 1 && spec.tenant_prefix_len > 0;
    let prefixes: Vec<Vec<i32>> = if tenanted {
        (0..spec.tenants)
            .map(|t| {
                let mut trng = Rng::new(spec.seed ^ (0x7e4a_0001 + t as u64));
                gen_prompt(&mut trng, spec.tenant_prefix_len)
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut out = Vec::with_capacity(spec.n_requests);
    let mut t = 0.0;
    for id in 0..spec.n_requests {
        let len = if spec.tail_alpha > 0.0 {
            // Pareto(alpha): mass near lo, a long tail toward hi
            let span = spec.prompt_len_hi - spec.prompt_len_lo;
            let x = (1.0 - rng.f64()).powf(-1.0 / spec.tail_alpha);
            let extra = ((x - 1.0) * span as f64 / 4.0).floor() as usize;
            spec.prompt_len_lo + extra.min(span)
        } else {
            spec.prompt_len_lo
                + rng.usize_below(
                    spec.prompt_len_hi - spec.prompt_len_lo + 1,
                )
        };
        let mut pick = rng.f64() * total_w;
        let mut config = spec.mix[0].0;
        for (c, w) in &spec.mix {
            if pick < *w {
                config = *c;
                break;
            }
            pick -= w;
        }
        // only draw when a range is configured, so specs predating the
        // knob keep their exact request streams
        let max_new = if spec.max_new_tokens_hi > spec.max_new_tokens {
            spec.max_new_tokens
                + rng.usize_below(
                    spec.max_new_tokens_hi - spec.max_new_tokens + 1,
                )
        } else {
            spec.max_new_tokens
        };
        // burst mode groups arrivals: only a burst head draws an
        // arrival gap, so a whole burst lands at the same instant.
        // burst_size <= 1 reduces to the old per-request draw exactly.
        if spec.rate > 0.0
            && (spec.burst_size <= 1 || id % spec.burst_size == 0)
        {
            t += rng.exp(spec.rate);
        }
        // only drawn when the knob is set, so specs predating it keep
        // their exact request streams
        let deadline_ticks = if spec.deadline_ticks > 0
            && rng.f64() < spec.deadline_frac
        {
            spec.deadline_ticks
        } else {
            0
        };
        // tenant mode: the tenant's fixed prefix + a per-request
        // grammar-word suffix (always >= 1 suffix token, so every
        // prompt diverges from its shared prefix)
        let prompt = if tenanted {
            let mut p = prefixes[id % spec.tenants].clone();
            let target = len.max(p.len() + 1);
            while p.len() < target {
                p.push(
                    vocab::WORD_A0
                        + rng.below(vocab::N_WORDS_A as u64) as i32,
                );
            }
            p
        } else {
            gen_prompt(&mut rng, len)
        };
        out.push(TimedRequest {
            at: t,
            req: Request {
                id: id as u64,
                prompt,
                max_new_tokens: max_new,
                config,
                deadline_ticks,
            },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_bounded() {
        let spec = WorkloadSpec::uniform_dense(50);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.req.prompt, y.req.prompt);
            assert!(x.req.prompt.len() >= 12 && x.req.prompt.len() <= 48);
            assert_eq!(x.req.prompt[0], vocab::BOS);
        }
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let mut spec = WorkloadSpec::uniform_dense(20);
        spec.rate = 100.0;
        let reqs = generate(&spec);
        for w in reqs.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        assert!(reqs.last().unwrap().at > 0.0);
    }

    #[test]
    fn shared_prefix_tenants_share_exact_prefixes() {
        let spec = WorkloadSpec::shared_prefix(12, 3, 16);
        let reqs = generate(&spec);
        assert_eq!(reqs.len(), 12);
        for (i, r) in reqs.iter().enumerate() {
            let peer = &reqs[i % 3].req.prompt; // same tenant as i
            assert_eq!(
                &r.req.prompt[..16],
                &peer[..16],
                "tenant {} prefix mismatch at request {i}",
                i % 3
            );
            assert!(r.req.prompt.len() > 16, "must diverge after prefix");
        }
        // distinct tenants get distinct prefixes
        assert_ne!(reqs[0].req.prompt[..16], reqs[1].req.prompt[..16]);
        assert_ne!(reqs[1].req.prompt[..16], reqs[2].req.prompt[..16]);
        // deterministic
        let again = generate(&spec);
        for (a, b) in reqs.iter().zip(again.iter()) {
            assert_eq!(a.req.prompt, b.req.prompt);
        }
    }

    #[test]
    fn heavy_tail_is_mostly_short_with_a_real_tail() {
        let spec = WorkloadSpec::heavy_tail(128);
        let reqs = generate(&spec);
        assert_eq!(reqs.len(), 128);
        let lens: Vec<usize> =
            reqs.iter().map(|r| r.req.prompt.len()).collect();
        for &l in &lens {
            assert!((8..=64).contains(&l), "len {l} out of bounds");
        }
        let short = lens.iter().filter(|&&l| l <= 24).count();
        let long = lens.iter().filter(|&&l| l >= 40).count();
        assert!(long >= 1, "no tail prompts at all");
        assert!(short > long, "short={short} long={long}: not heavy-tail");
        let budgets: Vec<usize> =
            reqs.iter().map(|r| r.req.max_new_tokens).collect();
        for &b in &budgets {
            assert!((1..=8).contains(&b), "budget {b} out of bounds");
        }
        assert!(
            budgets.iter().min() < budgets.iter().max(),
            "generation budgets did not vary"
        );
        // deterministic
        let again = generate(&spec);
        for (a, b) in reqs.iter().zip(again.iter()) {
            assert_eq!(a.req.prompt, b.req.prompt);
            assert_eq!(a.req.max_new_tokens, b.req.max_new_tokens);
        }
    }

    #[test]
    fn bursty_deadlines_groups_arrivals_and_mixes_deadlines() {
        let mut spec = WorkloadSpec::bursty_deadlines(40, 8, 12);
        spec.rate = 50.0; // gaps between bursts, none within
        let reqs = generate(&spec);
        assert_eq!(reqs.len(), 40);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(
                r.at,
                reqs[i - i % 8].at,
                "request {i} must share its burst head's arrival"
            );
        }
        assert!(
            reqs[0].at < reqs[8].at,
            "distinct bursts must be separated in time"
        );
        let with = reqs
            .iter()
            .filter(|r| r.req.deadline_ticks == 12)
            .count();
        let without = reqs
            .iter()
            .filter(|r| r.req.deadline_ticks == 0)
            .count();
        assert_eq!(with + without, 40, "deadline is 12 or absent");
        assert!(with >= 8, "deadline share too low: {with}/40");
        assert!(without >= 8, "deadline share too high: {with}/40");
        // deterministic
        let again = generate(&spec);
        for (a, b) in reqs.iter().zip(again.iter()) {
            assert_eq!(a.req.deadline_ticks, b.req.deadline_ticks);
            assert_eq!(a.at, b.at);
        }
    }

    #[test]
    fn legacy_specs_draw_identical_streams() {
        // the burst/deadline knobs must not disturb the RNG stream of
        // a spec that leaves them at their defaults
        let mut spec = WorkloadSpec::uniform_dense(30);
        spec.rate = 80.0;
        let a = generate(&spec);
        let mut again = spec.clone();
        again.burst_size = 0;
        again.deadline_ticks = 0;
        let b = generate(&again);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.deadline_ticks, 0);
        }
    }

    #[test]
    fn replica_schedules_are_deterministic_and_well_formed() {
        let a = replica_schedule(11, 3, 24, 500);
        let b = replica_schedule(11, 3, 24, 500);
        assert_eq!(a, b, "same seed must draw the same schedule");
        assert_eq!(a.len(), 24);
        let mut down: Vec<usize> = Vec::new();
        let mut last = 0u64;
        for e in &a {
            assert!(e.replica < 3, "slot {} out of range", e.replica);
            assert!(e.at_ms >= last, "events must be in fire order");
            last = e.at_ms;
            match e.action {
                ReplicaAction::Restart => {
                    assert!(
                        down.contains(&e.replica),
                        "restart of a slot nothing took down"
                    );
                    down.retain(|&i| i != e.replica);
                }
                _ => {
                    if !down.contains(&e.replica) {
                        down.push(e.replica);
                    }
                }
            }
        }
        let c = replica_schedule(12, 3, 24, 500);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn replica_schedule_does_not_disturb_request_streams() {
        let spec = WorkloadSpec::uniform_dense(20);
        let before = generate(&spec);
        let _chaos = replica_schedule(spec.seed, 4, 16, 1000);
        let after = generate(&spec);
        for (x, y) in before.iter().zip(after.iter()) {
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.at, y.at);
        }
    }

    #[test]
    fn mix_selects_all_configs() {
        let mut spec = WorkloadSpec::uniform_dense(200);
        spec.mix = vec![
            (SparsityConfig::dense(), 1.0),
            (SparsityConfig::amber(8, 16), 1.0),
        ];
        let reqs = generate(&spec);
        let dense = reqs
            .iter()
            .filter(|r| r.req.config.nm.is_none())
            .count();
        assert!(dense > 40 && dense < 160, "dense={dense}");
    }
}
