//! Workload generation for the serving benches: synthetic prompts drawn
//! from the same token world the models were trained on, poisson or burst
//! arrivals, and per-request sparsity-config mixes.

use crate::coordinator::request::{Request, SparsityConfig};
use crate::util::rng::Rng;

/// Token-vocabulary constants mirrored from python/compile/tokenizer.py.
pub mod vocab {
    /// beginning-of-sequence
    pub const BOS: i32 = 1;
    /// end-of-sequence
    pub const EOS: i32 = 2;
    /// fact-query marker
    pub const QRY: i32 = 4;
    /// answer marker
    pub const ANS: i32 = 5;
    /// first digit token (0–9 follow)
    pub const DIGIT0: i32 = 10;
    /// first relation token
    pub const REL0: i32 = 32;
    /// first entity token
    pub const ENT0: i32 = 48;
    /// first grammar-word token
    pub const WORD_A0: i32 = 80;
    /// grammar-word vocabulary size
    pub const N_WORDS_A: i32 = 128;
    /// first key token of the kv-pair sublanguage
    pub const KEY0: i32 = 336;
    /// key vocabulary size
    pub const N_KEYS: i32 = 48;
}

/// Shape of a synthetic serving workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// requests to generate
    pub n_requests: usize,
    /// mean requests/second for poisson arrivals (0 = all at once)
    pub rate: f64,
    /// shortest prompt, tokens
    pub prompt_len_lo: usize,
    /// longest prompt, tokens
    pub prompt_len_hi: usize,
    /// generation budget per request
    pub max_new_tokens: usize,
    /// sparsity mix: (config, weight)
    pub mix: Vec<(SparsityConfig, f64)>,
    /// RNG seed (same spec -> same workload)
    pub seed: u64,
}

impl WorkloadSpec {
    /// `n` all-dense requests with 12–48-token prompts, no arrival gaps.
    pub fn uniform_dense(n: usize) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: n,
            rate: 0.0,
            prompt_len_lo: 12,
            prompt_len_hi: 48,
            max_new_tokens: 8,
            mix: vec![(SparsityConfig::dense(), 1.0)],
            seed: 7,
        }
    }
}

/// A generated request + its arrival offset (seconds from start).
pub struct TimedRequest {
    /// arrival time, seconds from workload start
    pub at: f64,
    /// the request itself
    pub req: Request,
}

/// Grammar-like synthetic prompt (plausible in-distribution tokens).
pub fn gen_prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    let mut p = vec![vocab::BOS];
    while p.len() < len.saturating_sub(4) {
        match rng.below(4) {
            0 => {
                // fact query
                p.extend([
                    vocab::QRY,
                    vocab::ENT0 + rng.below(32) as i32,
                    vocab::REL0 + rng.below(8) as i32,
                    vocab::ANS,
                ]);
            }
            1 => {
                // grammar words
                for _ in 0..rng.below(6) + 2 {
                    p.push(vocab::WORD_A0 + rng.below(128) as i32);
                }
                p.push(vocab::EOS);
            }
            2 => {
                // kv pairs
                for _ in 0..rng.below(4) + 1 {
                    p.push(vocab::KEY0 + rng.below(vocab::N_KEYS as u64) as i32);
                    p.push(vocab::DIGIT0 + rng.below(10) as i32);
                }
            }
            _ => {
                // arithmetic
                p.extend([
                    vocab::DIGIT0 + rng.below(10) as i32,
                    20, // PLUS
                    vocab::DIGIT0 + rng.below(10) as i32,
                    23, // EQ
                ]);
            }
        }
    }
    // fill to exactly `len` with grammar words
    while p.len() < len {
        p.push(vocab::WORD_A0 + rng.below(vocab::N_WORDS_A as u64) as i32);
    }
    p.truncate(len);
    p
}

/// Generate the spec's full request schedule, deterministically.
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = Rng::new(spec.seed);
    let total_w: f64 = spec.mix.iter().map(|(_, w)| w).sum();
    let mut out = Vec::with_capacity(spec.n_requests);
    let mut t = 0.0;
    for id in 0..spec.n_requests {
        let len = spec.prompt_len_lo
            + rng.usize_below(spec.prompt_len_hi - spec.prompt_len_lo + 1);
        let mut pick = rng.f64() * total_w;
        let mut config = spec.mix[0].0;
        for (c, w) in &spec.mix {
            if pick < *w {
                config = *c;
                break;
            }
            pick -= w;
        }
        if spec.rate > 0.0 {
            t += rng.exp(spec.rate);
        }
        out.push(TimedRequest {
            at: t,
            req: Request {
                id: id as u64,
                prompt: gen_prompt(&mut rng, len),
                max_new_tokens: spec.max_new_tokens,
                config,
            },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_bounded() {
        let spec = WorkloadSpec::uniform_dense(50);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.req.prompt, y.req.prompt);
            assert!(x.req.prompt.len() >= 12 && x.req.prompt.len() <= 48);
            assert_eq!(x.req.prompt[0], vocab::BOS);
        }
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let mut spec = WorkloadSpec::uniform_dense(20);
        spec.rate = 100.0;
        let reqs = generate(&spec);
        for w in reqs.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        assert!(reqs.last().unwrap().at > 0.0);
    }

    #[test]
    fn mix_selects_all_configs() {
        let mut spec = WorkloadSpec::uniform_dense(200);
        spec.mix = vec![
            (SparsityConfig::dense(), 1.0),
            (SparsityConfig::amber(8, 16), 1.0),
        ];
        let reqs = generate(&spec);
        let dense = reqs
            .iter()
            .filter(|r| r.req.config.nm.is_none())
            .count();
        assert!(dense > 40 && dense < 160, "dense={dense}");
    }
}
