//! Serving deployment configuration (JSON file), so `amber serve
//! --config serve.json` captures a full deployment the way vLLM's engine
//! args do: model, artifact shapes, scheduler knobs, replica count,
//! default sparsity policy and admission limits.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::request::SparsityConfig;
use crate::util::json::Json;

/// One serving deployment, as read from `serve.json` (module docs).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// model to serve (manifest key)
    pub model: String,
    /// TCP bind address
    pub addr: String,
    /// prefill artifact sequence length
    pub prefill_seq: usize,
    /// partial-batch flush age, milliseconds
    pub max_wait_ms: f64,
    /// engine replicas behind the router
    pub replicas: usize,
    /// sparsity config for requests that name none
    pub default_sparsity: SparsityConfig,
    /// reject requests when this many are queued (backpressure)
    pub max_queue: usize,
    /// clamp per-request generation budgets to this many tokens
    pub max_new_tokens_cap: usize,
    /// queued-prompt-token backlog past which new requests degrade one
    /// N:M rung (0 = never degrade, the default)
    pub degrade_at: usize,
    /// queued-prompt-token backlog past which new requests are shed
    /// with a `rejected` response (0 = never shed, the default)
    pub shed_at: usize,
    /// transient failures tolerated per request before a `fatal`
    /// response
    pub max_retries: u32,
    /// replica heartbeat timeout, milliseconds: a replica that has not
    /// advanced its tick beacon for this long is declared hung and
    /// replaced (0 = heartbeat supervision off). Multi-replica only.
    pub heartbeat_ms: u64,
    /// replica crash/hang failovers tolerated per request before a
    /// `fatal` response (graceful-drain hand-backs are free)
    pub max_redispatch: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "tiny-lm-a".into(),
            addr: "127.0.0.1:8471".into(),
            prefill_seq: 64,
            max_wait_ms: 5.0,
            replicas: 1,
            default_sparsity: SparsityConfig::dense(),
            max_queue: 1024,
            max_new_tokens_cap: 64,
            degrade_at: 0,
            shed_at: 0,
            max_retries: 3,
            heartbeat_ms: 1000,
            max_redispatch: 3,
        }
    }
}

impl ServeConfig {
    /// Parse a config object; missing keys keep their defaults.
    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let get_s = |k: &str, dv: &str| {
            j.get(k)
                .and_then(|v| v.as_str())
                .unwrap_or(dv)
                .to_string()
        };
        let get_u =
            |k: &str, dv: usize| j.get(k).and_then(|v| v.as_usize()).unwrap_or(dv);
        let sparsity = j
            .get("default_sparsity")
            .and_then(|v| v.as_str())
            .map(|s| {
                SparsityConfig::parse(s)
                    .context(format!("bad default_sparsity '{s}'"))
            })
            .transpose()?
            .unwrap_or(d.default_sparsity);
        Ok(ServeConfig {
            model: get_s("model", &d.model),
            addr: get_s("addr", &d.addr),
            prefill_seq: get_u("prefill_seq", d.prefill_seq),
            max_wait_ms: j
                .get("max_wait_ms")
                .and_then(|v| v.as_f64())
                .unwrap_or(d.max_wait_ms),
            replicas: get_u("replicas", d.replicas),
            default_sparsity: sparsity,
            max_queue: get_u("max_queue", d.max_queue),
            max_new_tokens_cap: get_u("max_new_tokens_cap",
                                      d.max_new_tokens_cap),
            degrade_at: get_u("degrade_at", d.degrade_at),
            shed_at: get_u("shed_at", d.shed_at),
            max_retries: get_u("max_retries", d.max_retries as usize)
                as u32,
            heartbeat_ms: get_u("heartbeat_ms", d.heartbeat_ms as usize)
                as u64,
            max_redispatch: get_u(
                "max_redispatch",
                d.max_redispatch as usize,
            ) as u32,
        })
    }

    /// Load and parse a JSON config file.
    pub fn load(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let j = Json::parse(
            r#"{"model": "tiny-lm-b", "addr": "0.0.0.0:9000",
                "max_wait_ms": 2.5, "replicas": 2,
                "default_sparsity": "8:16:ls", "max_queue": 64}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "tiny-lm-b");
        assert_eq!(c.replicas, 2);
        assert_eq!(c.max_wait_ms, 2.5);
        assert_eq!(c.default_sparsity.nm, Some((8, 16)));
        assert_eq!(c.max_queue, 64);
        assert_eq!(c.prefill_seq, 64); // default
    }

    #[test]
    fn rejects_bad_sparsity() {
        let j = Json::parse(r#"{"default_sparsity": "nope"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn defaults() {
        let c = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.model, "tiny-lm-a");
        assert_eq!(c.max_queue, 1024);
        assert_eq!(c.degrade_at, 0, "overload control off by default");
        assert_eq!(c.shed_at, 0);
        assert_eq!(c.max_retries, 3);
        assert_eq!(c.heartbeat_ms, 1000);
        assert_eq!(c.max_redispatch, 3);
    }

    #[test]
    fn parses_replica_knobs() {
        let j = Json::parse(
            r#"{"replicas": 4, "heartbeat_ms": 250, "max_redispatch": 1}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.heartbeat_ms, 250);
        assert_eq!(c.max_redispatch, 1);
    }

    #[test]
    fn parses_overload_knobs() {
        let j = Json::parse(
            r#"{"degrade_at": 512, "shed_at": 2048, "max_retries": 5}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.degrade_at, 512);
        assert_eq!(c.shed_at, 2048);
        assert_eq!(c.max_retries, 5);
    }
}
