//! Mini property-testing kit (proptest is unavailable offline).
//!
//! Deterministic, seeded generators + a `prop_check` driver that reports
//! the first failing case with its seed so it can be replayed. Used for
//! the coordinator invariants (routing, batching, KV-slot management) and
//! the sparsity mask laws.

pub mod prop;

pub use prop::{prop_check, Gen};
