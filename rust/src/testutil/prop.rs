//! Property-testing driver: run a predicate over N seeded random cases;
//! on failure, retry the case with a simple halving shrink over the
//! generator's "size" knob and report the minimal failing seed.

use crate::util::rng::Rng;

/// A generator is any Fn(&mut Rng, usize /*size*/) -> T.
pub struct Gen;

impl Gen {
    /// Uniform integer in `lo..=hi`.
    pub fn usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize_below(hi - lo + 1)
    }

    /// `len` normal-distributed floats scaled by `scale`.
    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// Uniformly chosen element of `xs` (panics on empty input).
    pub fn choice<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
        &xs[rng.usize_below(xs.len())]
    }
}

/// Run `cases` random checks. `f(rng, size)` returns Err(description) on
/// property violation. Panics with the seed + description of the first
/// failure (replay by calling f with Rng::new(seed)).
pub fn prop_check<F>(name: &str, cases: usize, f: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        // grow the size knob over the run: early cases are small (easier
        // to debug), later cases stress harder.
        let size = 2 + case * 30 / cases.max(1);
        let mut rng = Rng::new(seed);
        if let Err(desc) = f(&mut rng, size) {
            // shrink: retry with smaller sizes, same seed
            let mut min_size = size;
            let mut min_desc = desc;
            let mut s = size / 2;
            while s >= 1 {
                let mut rng2 = Rng::new(seed);
                match f(&mut rng2, s) {
                    Err(d2) => {
                        min_size = s;
                        min_desc = d2;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={min_size}): \
                 {min_desc}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        prop_check("reverse-reverse", 50, |rng, size| {
            let v: Vec<u64> = (0..size).map(|_| rng.below(100)).collect();
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("reverse^2 != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        prop_check("always-fails", 5, |_, _| Err("nope".into()));
    }
}
