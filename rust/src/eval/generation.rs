//! Greedy-generation evaluation (GSM8K / LongBench analogues): sparse (or
//! dense) prefill hands its KV cache to the dense decode artifact —
//! exactly the paper's serving pipeline — and the generated continuation
//! is exact-matched against the gold tokens. Runs on any `Engine`
//! backend; caches move as host vectors.

use anyhow::{bail, Result};

use super::TaskResult;
use crate::runtime::Engine;
use crate::tensor::io::{EvalRows, EvalSet};
use crate::tensor::math::argmax;

/// Evaluate a generation dataset.
///
/// * `prefill_artifact` — dense/sparse/quant prefill at the dataset's
///   sequence length
/// * `decode_artifact`  — the model's decode artifact (batch B_dec,
///   cache C >= seq_len + max_gen)
#[allow(clippy::too_many_arguments)]
pub fn eval_generation(
    rt: &mut dyn Engine,
    prefill_artifact: &str,
    prefill_binding: &str,
    decode_artifact: &str,
    decode_binding: &str,
    task: &str,
    set: &EvalSet,
    limit: usize,
) -> Result<TaskResult> {
    let pmeta = rt.manifest().artifact(prefill_artifact)?.clone();
    let dmeta = rt.manifest().artifact(decode_artifact)?.clone();
    let (pb, s) = (pmeta.batch, pmeta.seq);
    let (db, cache) = (dmeta.batch, dmeta.cache);
    if s != set.seq_len {
        bail!("artifact seq {} != dataset {}", s, set.seq_len);
    }
    let rows = match &set.rows {
        EvalRows::Gen(r) => r,
        _ => bail!("{task}: not a generation dataset"),
    };
    let n = if limit == 0 { rows.len() } else { rows.len().min(limit) };
    // geometry for the KV shuttle
    let layers = dmeta
        .runtime_inputs
        .get(2)
        .map(|(shape, _)| shape[0])
        .unwrap_or(0);
    let (kv_heads, head_dim) = dmeta
        .runtime_inputs
        .get(2)
        .map(|(shape, _)| (shape[3], shape[4]))
        .unwrap_or((1, 1));

    let mut correct = 0usize;
    let mut exec_secs = 0.0;
    // chunk samples by min(prefill batch, decode batch)
    let chunk = pb.min(db);
    let mut i = 0;
    while i < n {
        let take = (n - i).min(chunk);
        let mut tokens = vec![0i32; pb * s];
        for j in 0..take {
            tokens[j * s..(j + 1) * s].copy_from_slice(set.row_tokens(i + j));
        }
        let out = rt.prefill(prefill_artifact, prefill_binding, &tokens)?;
        exec_secs += out.exec_secs;
        // scatter prefill rows into a fresh decode cache [L, DB, C, H, D]
        let row_sz = kv_heads * head_dim;
        let mut kc = vec![0f32; layers * db * cache * row_sz];
        let mut vc = vec![0f32; layers * db * cache * row_sz];
        let mut last = vec![0i32; db];
        let mut pos = vec![0i32; db];
        let mut kv_len = vec![1i32; db];
        let mut done = vec![true; db];
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); db];
        let mut max_gen = 0usize;
        for j in 0..take {
            let r = &rows[i + j];
            let plen = r.prompt_len as usize;
            for l in 0..layers {
                let src = l * pb * s * row_sz + j * s * row_sz;
                let dst = l * db * cache * row_sz + j * cache * row_sz;
                kc[dst..dst + plen * row_sz]
                    .copy_from_slice(&out.k_cache[src..src + plen * row_sz]);
                vc[dst..dst + plen * row_sz]
                    .copy_from_slice(&out.v_cache[src..src + plen * row_sz]);
            }
            // first generated token from the last prompt position
            let lrow = &out.logits
                [(j * s + plen - 1) * out.vocab..(j * s + plen) * out.vocab];
            let t0 = argmax(lrow) as i32;
            generated[j].push(t0);
            last[j] = t0;
            pos[j] = plen as i32;
            kv_len[j] = (plen + 1) as i32;
            done[j] = false;
            max_gen = max_gen.max(r.max_gen as usize);
        }
        // decode loop (step 1 already done via prefill logits)
        for _step in 1..max_gen {
            if done.iter().all(|d| *d) {
                break;
            }
            let dout = rt.decode(
                decode_artifact,
                decode_binding,
                &last,
                &pos,
                &kc,
                &vc,
                &kv_len,
            )?;
            exec_secs += dout.exec_secs;
            kc = dout.k_cache;
            vc = dout.v_cache;
            for j in 0..take {
                if done[j] {
                    continue;
                }
                let r = &rows[i + j];
                let lrow =
                    &dout.logits[j * dout.vocab..(j + 1) * dout.vocab];
                let t = argmax(lrow) as i32;
                generated[j].push(t);
                last[j] = t;
                pos[j] += 1;
                kv_len[j] += 1;
                if generated[j].len() >= r.max_gen as usize {
                    done[j] = true;
                }
            }
        }
        for j in 0..take {
            let r = &rows[i + j];
            let g = &generated[j];
            let ok = g.len() >= r.gold.len()
                && g[..r.gold.len()] == r.gold[..];
            correct += ok as usize;
        }
        i += take;
    }
    Ok(TaskResult {
        task: task.to_string(),
        accuracy: correct as f64 / n.max(1) as f64,
        n,
        exec_secs,
    })
}
