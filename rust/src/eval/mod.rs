//! Downstream-task evaluation over the execution engine (the measurement
//! half of the paper's tables); backend-neutral via `runtime::Engine`.
//!
//! Multiple-choice: each (context, choice) pair is one padded row in the
//! `.aev` dataset; the row's score is the sum of next-token log-probs over
//! the choice span (lm-eval-harness convention); accuracy = mean over
//! samples of argmax(choice score) == gold.
//!
//! Generation: rows are prompts; the engine prefills, then greedily
//! decodes `max_gen` tokens through the decode executable; exact-match of
//! the first `gold.len()` generated tokens (the worked intermediate step
//! AND the final answer for the GSM8K analogue).

pub mod generation;
pub mod mc;

pub use generation::eval_generation;
pub use mc::eval_multiple_choice;

use std::path::Path;

use anyhow::Result;

use crate::tensor::io::{read_eval, EvalSet};

/// Load an `.aev` eval dataset from `<artifacts>/eval/<file>`.
pub fn load_task(artifacts: &Path, file: &str) -> Result<EvalSet> {
    read_eval(&artifacts.join("eval").join(file))
}

/// Accuracy result of one (task, setting) cell.
#[derive(Debug, Clone)]
pub struct TaskResult {
    /// task name (dataset stem)
    pub task: String,
    /// fraction of samples answered correctly
    pub accuracy: f64,
    /// samples evaluated
    pub n: usize,
    /// engine execution seconds spent
    pub exec_secs: f64,
}
