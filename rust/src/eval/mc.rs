//! Multiple-choice scoring through a prefill artifact (any backend).

use anyhow::{bail, Result};

use super::TaskResult;
use crate::runtime::Engine;
use crate::tensor::io::{EvalRows, EvalSet};
use crate::tensor::math::span_logprob;

/// Evaluate one MC dataset through `artifact` (+ weight `binding`).
/// `limit` truncates to the first N samples (0 = all).
pub fn eval_multiple_choice(
    rt: &mut dyn Engine,
    artifact: &str,
    binding: &str,
    task: &str,
    set: &EvalSet,
    limit: usize,
) -> Result<TaskResult> {
    let meta = rt.manifest().artifact(artifact)?.clone();
    let (b, s) = (meta.batch, meta.seq);
    if s != set.seq_len {
        bail!(
            "artifact seq {} != dataset seq {} for task {task}",
            s,
            set.seq_len
        );
    }
    let rows = match &set.rows {
        EvalRows::Mc(r) => r,
        _ => bail!("{task}: not a multiple-choice dataset"),
    };
    let n_rows = if limit == 0 {
        rows.len()
    } else {
        // keep whole samples: limit * n_choices rows
        (limit * set.n_choices).min(rows.len())
    };
    let mut scores: Vec<f64> = vec![f64::NEG_INFINITY; n_rows];
    let mut exec_secs = 0.0;
    let mut batch_tokens = vec![0i32; b * s];
    let mut i = 0;
    while i < n_rows {
        let take = (n_rows - i).min(b);
        batch_tokens.fill(0);
        for j in 0..take {
            batch_tokens[j * s..(j + 1) * s]
                .copy_from_slice(set.row_tokens(i + j));
        }
        let out = rt.prefill(artifact, binding, &batch_tokens)?;
        exec_secs += out.exec_secs;
        for j in 0..take {
            let r = &rows[i + j];
            let toks = set.row_tokens(i + j);
            let span = &toks[r.score_start as usize
                ..(r.score_start + r.score_len) as usize];
            let logits =
                &out.logits[j * s * out.vocab..(j + 1) * s * out.vocab];
            scores[i + j] = span_logprob(
                logits,
                out.vocab,
                r.score_start as usize,
                span,
            );
        }
        i += take;
    }
    // aggregate per sample
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut cur_sample = u32::MAX;
    let mut best = f64::NEG_INFINITY;
    let mut best_choice = 0u16;
    let mut gold = 0u16;
    let mut n_seen = 0usize;
    for (idx, r) in rows.iter().take(n_rows).enumerate() {
        if r.sample != cur_sample {
            if cur_sample != u32::MAX && n_seen == set.n_choices {
                total += 1;
                correct += (best_choice == gold) as usize;
            }
            cur_sample = r.sample;
            best = f64::NEG_INFINITY;
            n_seen = 0;
            gold = r.gold;
        }
        n_seen += 1;
        if scores[idx] > best {
            best = scores[idx];
            best_choice = r.choice;
        }
    }
    if cur_sample != u32::MAX && n_seen == set.n_choices {
        total += 1;
        correct += (best_choice == gold) as usize;
    }
    Ok(TaskResult {
        task: task.to_string(),
        accuracy: correct as f64 / total.max(1) as f64,
        n: total,
        exec_secs,
    })
}
