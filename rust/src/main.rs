//! `amber` — the Amber Pruner serving CLI (Layer-3 leader binary).
//!
//! Subcommands:
//!   amber info                         — artifact inventory + platform
//!   amber serve    [--addr ...]        — TCP serving front-end
//!   amber bench-serve [...]            — closed-loop serving benchmark
//!   amber repro <target> [...]         — regenerate a paper table/figure
//!   amber eval  [...]                  — run one eval cell directly
//!
//! Every subcommand takes `--engine native` (default; pure-CPU, works
//! with or without an artifacts directory) or `--engine pjrt` (requires
//! building with `--features pjrt` and a compiled artifacts/ tree).

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use amber_pruner::coordinator::replica::{
    EngineFactory, Gateway, PoolConfig, ReplicaPool,
};
use amber_pruner::coordinator::request::SparsityConfig;
use amber_pruner::coordinator::scheduler::{Engine, EngineConfig, EngineMsg};
use amber_pruner::server::config::ServeConfig;
use amber_pruner::eval::{eval_multiple_choice, load_task};
use amber_pruner::metrics::{EngineMetrics, Timer};
use amber_pruner::repro::{self, ReproCtx};
use amber_pruner::runtime::{engine_for, Engine as ExecEngine};
use amber_pruner::server::{tcp, workload};
use amber_pruner::util::cli::Args;

const USAGE: &str = "\
amber — N:M activation-sparse LLM serving (Amber Pruner reproduction)

USAGE:
  amber info      [--artifacts DIR] [--engine native|pjrt]
  amber serve     [--artifacts DIR] [--model NAME] [--addr HOST:PORT]
                  [--replicas N] [--config serve.json]
  amber bench-serve [--artifacts DIR] [--model NAME] [--requests N]
                  [--rate R] [--sparsity CFG] [--max-new N]
                  [--replicas N]
  amber repro     TARGET [--artifacts DIR] [--limit N] [--model NAME]
                  (TARGET: table1 table2 table3 app-table1 fig2 fig34
                           fig6 appc coverage all)
  amber eval      --artifact NAME --weights F1[,F2] --task T
                  [--artifacts DIR] [--limit N]

Sparsity configs: dense | N:M[:naive|ls|all][+sq]   e.g. 8:16:ls+sq
Engines: native (default, pure-CPU) | pjrt (needs --features pjrt)
";

fn artifacts_dir(args: &Args) -> PathBuf {
    let p = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    if !p.join("manifest.json").exists() {
        // convenience: resolve relative to the repo root when invoked
        // from a subdirectory (e.g. python/)
        for up in ["..", "../.."] {
            let alt = PathBuf::from(up).join(&p);
            if alt.join("manifest.json").exists() {
                return alt;
            }
        }
    }
    p
}

/// Build the execution backend named by `kind` (callable from replica
/// threads — backends are not `Send`, so each replica builds its own).
fn backend_for(
    dir: &std::path::Path,
    kind: &str,
) -> Result<Box<dyn ExecEngine>> {
    match kind {
        "native" => engine_for(dir),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(
            amber_pruner::runtime::ModelRuntime::new(dir)?,
        )),
        other => bail!(
            "unknown --engine '{other}' (available: native{})",
            if cfg!(feature = "pjrt") {
                ", pjrt"
            } else {
                "; rebuild with --features pjrt for the PJRT backend"
            }
        ),
    }
}

/// Build the `--engine`-selected execution backend.
fn make_engine(
    dir: &std::path::Path,
    args: &Args,
) -> Result<Box<dyn ExecEngine>> {
    backend_for(dir, args.opt("engine").unwrap_or("native"))
}

/// Coordinator engine config derived from a serving deployment.
fn engine_config(scfg: &ServeConfig) -> EngineConfig {
    let mut ecfg = EngineConfig::new(&scfg.model);
    ecfg.prefill_seq = scfg.prefill_seq;
    ecfg.max_wait_secs = scfg.max_wait_ms / 1e3;
    ecfg.max_retries = scfg.max_retries;
    if scfg.degrade_at > 0 || scfg.shed_at > 0 {
        ecfg.degrade_policy =
            Some(amber_pruner::coordinator::scheduler::DegradePolicy {
                degrade_at: scfg.degrade_at,
                shed_at: scfg.shed_at,
            });
    }
    ecfg
}

/// Replica-pool factory: rebuilds backend + engine inside each replica
/// thread (and on every supervised restart).
fn pool_factory(
    dir: PathBuf,
    engine_kind: String,
    scfg: ServeConfig,
    metrics: Arc<EngineMetrics>,
) -> EngineFactory {
    Arc::new(move |_i| {
        let rt = backend_for(&dir, &engine_kind)?;
        Engine::new(rt, engine_config(&scfg), Arc::clone(&metrics))
    })
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "artifacts", "model", "addr", "requests", "rate", "sparsity",
        "max-new", "limit", "artifact", "weights", "task", "config",
        "engine", "replicas",
    ])?;
    let cmd = args.positional.first().map(|s| s.as_str());
    match cmd {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("bench-serve") => bench_serve(&args),
        Some("repro") => {
            let target = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let ctx = ReproCtx {
                artifacts: &artifacts_dir(&args),
                limit: args.opt_usize("limit", 0)?,
                model: args.opt("model").map(String::from),
            };
            repro::run(target, &ctx)
        }
        Some("eval") => eval_cell(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = make_engine(&dir, args)?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", dir.display());
    println!("\nmodels:");
    for (name, m) in &rt.manifest().models {
        println!(
            "  {name}{}  config={:?}",
            if m.is_moe { " (MoE)" } else { "" },
            m.config
        );
    }
    println!("\nartifacts ({}):", rt.manifest().artifacts.len());
    for (name, a) in &rt.manifest().artifacts {
        println!(
            "  {name:<44} {}x{}  {} params, variant={}",
            a.batch,
            if a.kind == "prefill" { a.seq } else { a.cache },
            a.params.len(),
            a.variant
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut scfg = match args.opt("config") {
        Some(p) => ServeConfig::load(std::path::Path::new(p))?,
        None => ServeConfig::default(),
    };
    if let Some(m) = args.opt("model") {
        scfg.model = m.to_string();
    }
    if let Some(a) = args.opt("addr") {
        scfg.addr = a.to_string();
    }
    scfg.replicas = args.opt_usize("replicas", scfg.replicas)?;
    let metrics = Arc::new(EngineMetrics::new());
    if scfg.replicas <= 1 {
        // classic single-engine deployment: the engine runs on the
        // main thread, behind a Direct gateway
        let rt = make_engine(&dir, args)?;
        let mut engine =
            Engine::new(rt, engine_config(&scfg), Arc::clone(&metrics))?;
        let (tx, rx) = channel::<EngineMsg>();
        let (bound, _h) = tcp::serve(
            &scfg.addr,
            Gateway::Direct(tx),
            Arc::clone(&metrics),
        )?;
        println!("serving {} on {bound} (ctrl-c to stop)", scfg.model);
        engine.run(rx)?;
        return Ok(());
    }
    // supervised replica pool: N engine threads, crash failover,
    // graceful drain on the TCP `shutdown` command
    let engine_kind =
        args.opt("engine").unwrap_or("native").to_string();
    let factory = pool_factory(
        dir,
        engine_kind,
        scfg.clone(),
        Arc::clone(&metrics),
    );
    let mut pcfg = PoolConfig::new(scfg.replicas);
    pcfg.heartbeat_timeout = Duration::from_millis(scfg.heartbeat_ms);
    pcfg.max_redispatch = scfg.max_redispatch;
    let mut pool =
        ReplicaPool::start(factory, Arc::clone(&metrics), pcfg)?;
    let gateway = Gateway::Pool(pool.handle());
    let (bound, _h) =
        tcp::serve(&scfg.addr, gateway, Arc::clone(&metrics))?;
    println!(
        "serving {} on {bound} across {} replicas \
         (send {{\"cmd\": \"shutdown\"}} to drain)",
        scfg.model, scfg.replicas
    );
    pool.wait()?;
    Ok(())
}

fn bench_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let model = args.opt_or("model", "tiny-lm-a");
    let n = args.opt_usize("requests", 64)?;
    let rate = args.opt_f64("rate", 0.0)?;
    let max_new = args.opt_usize("max-new", 8)?;
    let sparsity = args.opt_or("sparsity", "8:16:ls");
    let cfg = SparsityConfig::parse(&sparsity)
        .ok_or_else(|| anyhow::anyhow!("bad --sparsity {sparsity}"))?;

    let replicas = args.opt_usize("replicas", 1)?;

    let metrics = Arc::new(EngineMetrics::new());

    let mut spec = workload::WorkloadSpec::uniform_dense(n);
    spec.rate = rate;
    spec.max_new_tokens = max_new;
    spec.mix = vec![(cfg, 1.0)];
    let reqs = workload::generate(&spec);

    if replicas > 1 {
        // pool path: submit through the supervisor, drain, report
        let scfg = ServeConfig {
            model: model.to_string(),
            ..ServeConfig::default()
        };
        let factory = pool_factory(
            dir,
            args.opt("engine").unwrap_or("native").to_string(),
            scfg,
            Arc::clone(&metrics),
        );
        let mut pool = ReplicaPool::start(
            factory,
            Arc::clone(&metrics),
            PoolConfig::new(replicas),
        )?;
        let handle = pool.handle();
        let (reply_tx, reply_rx) = channel();
        let t = Timer::start();
        let start = std::time::Instant::now();
        for tr in reqs {
            let dt = tr.at - start.elapsed().as_secs_f64();
            if dt > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(dt));
            }
            handle.submit(tr.req, reply_tx.clone())?;
        }
        let mut got = 0usize;
        for _ in 0..n {
            match reply_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(_) => got += 1,
                Err(_) => break,
            }
        }
        pool.shutdown()?;
        let wall = t.secs();
        println!(
            "\n== bench-serve {model} sparsity={} requests={n} \
             rate={rate} replicas={replicas} ==",
            cfg.label()
        );
        println!("completed {got}/{n} in {wall:.2}s");
        println!("{}", metrics.report(wall));
        return Ok(());
    }

    let rt = make_engine(&dir, args)?;
    let mut engine =
        Engine::new(rt, EngineConfig::new(&model), Arc::clone(&metrics))?;

    let (reply_tx, reply_rx) = channel();
    let t = Timer::start();
    // closed-loop: submit respecting arrival offsets, then drain
    let (tx, rx) = channel::<EngineMsg>();
    let submitter = std::thread::spawn(move || {
        let start = std::time::Instant::now();
        for tr in reqs {
            let dt = tr.at - start.elapsed().as_secs_f64();
            if dt > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt));
            }
            if tx.send(EngineMsg::Submit(tr.req, reply_tx.clone())).is_err()
            {
                return;
            }
        }
        // closing tx ends the engine loop once queues drain
    });
    engine.run(rx)?;
    submitter.join().ok();
    let wall = t.secs();
    let got = reply_rx.try_iter().count();
    println!(
        "\n== bench-serve {model} sparsity={} requests={n} rate={rate} ==",
        cfg.label()
    );
    println!("completed {got}/{n} in {wall:.2}s");
    println!("{}", metrics.report(wall));
    if let Some(audit) = engine.audit() {
        println!(
            "sparsity: {} pruned / {} dense matmuls, {:.1}% linear FLOPs \
             saved, {} N:M violations, {} dense fallbacks",
            audit.pruned_matmuls,
            audit.dense_matmuls,
            audit.flops_saved_frac() * 100.0,
            audit.nm_violations,
            audit.pruned_fallbacks
        );
    }
    engine.kv_invariants()?;
    Ok(())
}

fn eval_cell(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let artifact = args
        .opt("artifact")
        .ok_or_else(|| anyhow::anyhow!("--artifact required"))?
        .to_string();
    let weights: Vec<String> = args
        .opt("weights")
        .ok_or_else(|| anyhow::anyhow!("--weights required"))?
        .split(',')
        .map(String::from)
        .collect();
    let task = args
        .opt("task")
        .ok_or_else(|| anyhow::anyhow!("--task required"))?
        .to_string();
    let limit = args.opt_usize("limit", 0)?;
    let mut rt = make_engine(&dir, args)?;
    let wrefs: Vec<&str> = weights.iter().map(|s| s.as_str()).collect();
    let binding = rt.bind(&artifact, &wrefs)?;
    let set = load_task(&dir, &format!("{task}.aev"))?;
    match set.rows {
        amber_pruner::tensor::io::EvalRows::Mc(_) => {
            let r = eval_multiple_choice(
                &mut *rt,
                &artifact,
                &binding,
                &task,
                &set,
                limit,
            )?;
            println!(
                "{task}: accuracy {:.4} over {} samples ({:.2}s exec)",
                r.accuracy, r.n, r.exec_secs
            );
        }
        _ => bail!("use `repro table3` for generation tasks"),
    }
    Ok(())
}
