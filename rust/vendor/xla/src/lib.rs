//! Compile-only stub of the `xla` (PJRT) crate surface the `pjrt`
//! feature of `amber_pruner` links against.
//!
//! The offline build environment cannot fetch the real `xla` crate, but
//! the PJRT engine path must still typecheck under `--features pjrt`
//! (ISSUE 1 acceptance). Every constructor here returns
//! `Error::Unavailable`, so using the stub at runtime fails fast with a
//! clear message. A real deployment replaces the `path` dependency in
//! rust/Cargo.toml with the actual crate; no amber_pruner source changes.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// The stub backend: PJRT is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real xla/PJRT crate \
                 (offline build links rust/vendor/xla)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
    U8,
}

/// Host-side literal (stub: never constructed successfully).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("requires the real xla"));
    }
}
