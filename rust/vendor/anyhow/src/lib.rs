//! Offline shim of the `anyhow` API surface used by `amber_pruner`.
//!
//! The build environment has no crates.io access, so this path-vendored
//! crate supplies the subset the codebase relies on: `Result`/`Error`,
//! the `anyhow!` and `bail!` macros, and the `Context` extension trait
//! over `Result` and `Option`. Error chains are flattened into one
//! message string ("context: cause"), which is what the callers format
//! with `{e}` / `{e:#}` anyway. Swapping back to the real crate is a
//! one-line change in Cargo.toml; no call sites change.

use std::fmt;

/// `anyhow::Result`, with the same default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened error: the full "context: cause" chain in one string.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer, outermost first (anyhow convention).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the flattened chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real anyhow::Error, this type deliberately does NOT
// implement std::error::Error — that is what makes the blanket From
// impl below coherent (no overlap with `impl From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        // include one level of source, which covers the io::Error-style
        // wrappers this crate encounters
        match e.source() {
            Some(src) => Error { msg: format!("{e}: {src}") },
            None => Error::msg(&e),
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!(fmt, ...)` — construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!(fmt, ...)` — early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/\u{0}")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_layers_prepend() {
        let r: Result<(), Error> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 3");
        let o: Option<u32> = None;
        let e2 = o.with_context(|| "missing").unwrap_err();
        assert_eq!(e2.to_string(), "missing");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: bool) -> Result<u32> {
            if x {
                bail!("nope {x}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope true");
    }
}
