//! Quickstart: run one sparse prefill and a few decode steps by hand —
//! the minimal end-to-end path through the public API
//! (engine -> prefill -> KV handoff -> decode).
//!
//!     cargo run --release --example quickstart
//!
//! Works out of the box: with an `artifacts/` tree the engine adopts its
//! manifest; without one it serves the synthetic tiny-lm inventory.

use anyhow::Result;

use amber_pruner::runtime::{engine_for, Engine as _};
use amber_pruner::tensor::math::argmax;

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    let mut rt = engine_for(dir)?;
    println!("engine platform: {}", rt.platform());

    let model = "tiny-lm-a";
    // pick the 8:16 Amber-Pruner prefill if present, then 2:4, then the
    // dense artifact (always present) so dense-only artifact trees run
    let nm8 = format!("{model}.prefill64.nm8_16");
    let nm2 = format!("{model}.prefill64.nm2_4");
    let have = |a: &str| rt.manifest().artifacts.contains_key(a);
    let (prefill, files): (String, Vec<String>) = if have(&nm8) {
        (
            nm8,
            vec![format!("{model}.atw"), format!("{model}.aux_ls.atw")],
        )
    } else if have(&nm2) {
        (
            nm2,
            vec![format!("{model}.atw"), format!("{model}.aux_ls.atw")],
        )
    } else {
        (
            format!("{model}.prefill64.dense"),
            vec![format!("{model}.atw")],
        )
    };
    let refs: Vec<&str> = files.iter().map(|s| s.as_str()).collect();
    let t0 = std::time::Instant::now();
    let binding = rt.bind(&prefill, &refs)?;
    println!(
        "prepared + bound {prefill} in {:.2}s",
        t0.elapsed().as_secs_f64()
    );

    // a fact-recall prompt: "<bos> <qry> E3 r1 <ans>" (the model answers
    // with the entity its training world pairs with (E3, r1))
    let meta = rt.manifest().artifact(&prefill)?.clone();
    let (b, s) = (meta.batch, meta.seq);
    let prompt = vec![1, 4, 51, 33, 5]; // BOS QRY E3 r1 ANS
    let mut tokens = vec![0i32; b * s];
    tokens[..prompt.len()].copy_from_slice(&prompt);
    let out = rt.prefill(&prefill, &binding, &tokens)?;
    println!(
        "prefill [{}x{}] -> logits [{b},{s},{}] in {:.1}ms",
        b, s, out.vocab, out.exec_secs * 1e3
    );
    let last = &out.logits
        [(prompt.len() - 1) * out.vocab..prompt.len() * out.vocab];
    let mut tok = argmax(last) as i32;
    println!("first generated token: {tok}");

    // hand-rolled decode loop over the dense decode artifact
    let decode = format!("{model}.decode.dense");
    let dbind = rt.bind(&decode, &[&files[0]])?;
    let dmeta = rt.manifest().artifact(&decode)?.clone();
    let dims = &dmeta.runtime_inputs[2].0;
    let (l, db, c, h, d) = (dims[0], dims[1], dims[2], dims[3], dims[4]);
    // scatter row 0 of the prefill cache into slot 0
    let row = h * d;
    let mut kc = vec![0f32; l * db * c * row];
    let mut vc = vec![0f32; l * db * c * row];
    let plen = prompt.len();
    for li in 0..l {
        let src = li * b * s * row;
        let dst = li * db * c * row;
        kc[dst..dst + plen * row]
            .copy_from_slice(&out.k_cache[src..src + plen * row]);
        vc[dst..dst + plen * row]
            .copy_from_slice(&out.v_cache[src..src + plen * row]);
    }
    let mut generated = vec![tok];
    let mut pos = plen as i32;
    for _ in 0..4 {
        let mut token_v = vec![0i32; db];
        token_v[0] = tok;
        let mut pos_v = vec![0i32; db];
        pos_v[0] = pos;
        let mut len_v = vec![1i32; db];
        len_v[0] = pos + 1;
        let dout = rt.decode(
            &decode, &dbind, &token_v, &pos_v, &kc, &vc, &len_v,
        )?;
        kc = dout.k_cache;
        vc = dout.v_cache;
        tok = argmax(&dout.logits[..dout.vocab]) as i32;
        generated.push(tok);
        pos += 1;
        if tok == 2 {
            break; // EOS
        }
    }
    println!("generated tokens: {generated:?}");
    println!("quickstart OK");
    Ok(())
}
