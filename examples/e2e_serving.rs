//! End-to-end serving driver (the DESIGN.md "end-to-end validation"
//! deliverable): boots the full stack — execution engine, block-paged
//! KV store, continuous-batching scheduler — serves a batched
//! mixed-sparsity workload through the real engine loop, and reports
//! latency/throughput + an output-quality spot check. Runs on the native
//! CPU backend out of the box (an `artifacts/` manifest is optional).
//!
//!     cargo run --release --example e2e_serving [-- --requests 48]

use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::Result;

use amber_pruner::coordinator::request::SparsityConfig;
use amber_pruner::coordinator::scheduler::{Engine, EngineConfig, EngineMsg};
use amber_pruner::metrics::{EngineMetrics, Timer};
use amber_pruner::runtime::{engine_for, Engine as _};
use amber_pruner::server::workload::{self, WorkloadSpec};
use amber_pruner::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["requests", "rate", "model", "artifacts"])?;
    let dir = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let model = args.opt_or("model", "tiny-lm-a");
    let n = args.opt_usize("requests", 48)?;
    let rate = args.opt_f64("rate", 20.0)?;

    let metrics = Arc::new(EngineMetrics::new());
    let rt = engine_for(&dir)?;
    println!("platform={} model={model}", rt.platform());
    let mut engine =
        Engine::new(rt, EngineConfig::new(&model), Arc::clone(&metrics))?;

    // mixed workload: dense + all three Amber ratios, poisson arrivals —
    // the paper's serving scenario with per-request sparsity as a knob.
    let mut spec = WorkloadSpec::uniform_dense(n);
    spec.rate = rate;
    spec.max_new_tokens = 6;
    spec.seed = 2024;
    spec.mix = vec![
        (SparsityConfig::dense(), 1.0),
        (SparsityConfig { setting:
            amber_pruner::sparsity::policy::Setting::LayerSkip,
            nm: Some((2, 4)), quantized: false }, 1.0),
        (SparsityConfig { setting:
            amber_pruner::sparsity::policy::Setting::LayerSkip,
            nm: Some((4, 8)), quantized: false }, 1.0),
        (SparsityConfig { setting:
            amber_pruner::sparsity::policy::Setting::LayerSkip,
            nm: Some((8, 16)), quantized: false }, 1.0),
    ];
    let reqs = workload::generate(&spec);
    println!("submitting {n} requests at ~{rate}/s (mixed sparsity)");

    let (reply_tx, reply_rx) = channel();
    let (tx, rx) = channel::<EngineMsg>();
    let t = Timer::start();
    let submitter = std::thread::spawn(move || {
        let start = std::time::Instant::now();
        for tr in reqs {
            let dt = tr.at - start.elapsed().as_secs_f64();
            if dt > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt));
            }
            if tx.send(EngineMsg::Submit(tr.req, reply_tx.clone())).is_err()
            {
                return;
            }
        }
    });
    engine.run(rx)?;
    submitter.join().ok();
    let wall = t.secs();

    let responses: Vec<_> = reply_rx.try_iter().collect();
    println!("\ncompleted {}/{} in {wall:.2}s", responses.len(), n);
    println!("{}", metrics.report(wall));
    if let Some(audit) = engine.audit() {
        println!(
            "sparsity: {} pruned matmuls, {:.1}% linear FLOPs saved, \
             {} N:M violations",
            audit.pruned_matmuls,
            audit.flops_saved_frac() * 100.0,
            audit.nm_violations
        );
    }
    engine.kv_invariants()?;

    // quality spot check: every response generated tokens; non-trivial
    // fraction ends with EOS or produced max_new tokens.
    let full = responses
        .iter()
        .filter(|r| r.tokens.len() == 6 || r.tokens.last() == Some(&2))
        .count();
    println!(
        "responses with full generations: {full}/{}",
        responses.len()
    );
    assert_eq!(responses.len(), n, "all requests must complete");
    println!("e2e_serving OK");
    Ok(())
}
