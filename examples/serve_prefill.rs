//! TCP serving demo: boots the engine + TCP front-end, then acts as its
//! own client — connects, sends JSON requests at several sparsity configs,
//! prints responses, queries stats, and shuts down. Demonstrates the wire
//! protocol a real deployment would speak.
//!
//!     cargo run --release --example serve_prefill

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::Result;

use amber_pruner::coordinator::scheduler::{Engine, EngineConfig, EngineMsg};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::engine_for;
use amber_pruner::server::tcp;

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    let metrics = Arc::new(EngineMetrics::new());
    let rt = engine_for(dir)?;
    let mut engine = Engine::new(
        rt,
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )?;
    let (tx, rx) = channel::<EngineMsg>();
    let (addr, _acceptor) =
        tcp::serve("127.0.0.1:0", tx.clone(), Arc::clone(&metrics))?;
    println!("engine listening on {addr}");

    // client thread: speak the line protocol
    let client = std::thread::spawn(move || -> Result<Vec<String>> {
        let stream = TcpStream::connect(addr)?;
        let mut w = stream.try_clone()?;
        let mut r = BufReader::new(stream);
        let mut out = Vec::new();
        let prompts = [
            // "<bos> <qry> E0 r0 <ans>" at different sparsity configs
            (r#"{"id":1,"prompt":[1,4,48,32,5],"max_new_tokens":3,"sparsity":"dense"}"#,),
            (r#"{"id":2,"prompt":[1,4,49,33,5],"max_new_tokens":3,"sparsity":"2:4:ls"}"#,),
            (r#"{"id":3,"prompt":[1,4,50,34,5],"max_new_tokens":3,"sparsity":"8:16:ls"}"#,),
            (r#"{"id":4,"prompt":[1,10,20,13,23],"max_new_tokens":3,"sparsity":"4:8:ls"}"#,),
        ];
        for (p,) in prompts {
            writeln!(w, "{p}")?;
            let mut line = String::new();
            r.read_line(&mut line)?;
            out.push(line.trim().to_string());
        }
        writeln!(w, r#"{{"cmd":"stats"}}"#)?;
        let mut line = String::new();
        r.read_line(&mut line)?;
        out.push(line.trim().to_string());
        writeln!(w, r#"{{"cmd":"quit"}}"#)?;
        Ok(out)
    });

    // run the engine until the client is done, then shut down
    let shutdown = std::thread::spawn(move || {
        let lines = client.join().expect("client thread")?;
        for l in &lines {
            println!("<- {l}");
        }
        let _ = tx.send(EngineMsg::Shutdown);
        Ok::<Vec<String>, anyhow::Error>(lines)
    });
    engine.run(rx)?;
    let lines = shutdown.join().expect("shutdown thread")?;
    assert!(lines.len() == 5, "expected 4 responses + stats");
    assert!(lines[4].contains("requests_completed"));
    println!("serve_prefill OK");
    Ok(())
}
