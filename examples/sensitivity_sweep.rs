//! Sensitivity / policy explorer: prints the per-layer, per-module e_q
//! sensitivity table (Appendix D data, computed at build time on real
//! activations), the derived skip policy, and what-if coverage numbers
//! for alternative skip budgets — the workflow an operator would use to
//! tune the accuracy/coverage trade-off on a new model.
//!
//!     cargo run --release --example sensitivity_sweep [-- --model NAME]

use anyhow::{Context, Result};

use amber_pruner::runtime::Manifest;
use amber_pruner::sparsity::coverage::Geometry;
use amber_pruner::sparsity::policy;
use amber_pruner::util::cli::Args;
use amber_pruner::util::fmt::Table;
use amber_pruner::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env(&["model", "artifacts"])?;
    let dir = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let model = args.opt_or("model", "tiny-lm-a");

    let manifest = Manifest::load(&dir)?;
    let info = manifest
        .models
        .get(&model)
        .with_context(|| format!("model {model} not in manifest"))?;
    let g = Geometry::from_config(&info.config);

    let stats_path = dir.join("stats").join(format!(
        "sensitivity_{model}.json"
    ));
    let j = Json::parse(&std::fs::read_to_string(&stats_path)?)?;
    let modules: Vec<String> = j
        .req("modules")?
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.as_str().unwrap().to_string())
        .collect();
    let per_layer = j.req("per_layer")?.as_arr().unwrap();

    let mut t = Table::new(
        &format!("per-(layer, module) sensitivity e_q — {model} @ 4:8"),
        &[&["layer"][..],
          &modules.iter().map(|s| s.as_str()).collect::<Vec<_>>()[..]]
            .concat(),
    );
    for (li, row) in per_layer.iter().enumerate() {
        let mut cells = vec![li.to_string()];
        for v in row.as_arr().unwrap() {
            cells.push(format!("{:.4}", v.as_f64().unwrap()));
        }
        t.row(cells);
    }
    t.print();

    let skips: Vec<usize> = j
        .req("skip_layers")?
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    println!("\nchosen q/gate skip layers: {skips:?}");
    println!(
        "prunable module types: {:?}",
        policy::MODULES
            .iter()
            .filter(|m| policy::prunable(m))
            .collect::<Vec<_>>()
    );

    // what-if: coverage + ideal speedup across skip budgets
    let mut w = Table::new(
        "what-if: q/gate skip budget vs coverage",
        &["skipped layers", "coverage", "ideal 2:4 speedup",
          "ideal 8:16 speedup"],
    );
    // rank layers by the build-time sensitivity (q + gate columns)
    let qi = modules.iter().position(|m| m == "q_proj").unwrap();
    let gi = modules.iter().position(|m| m == "gate_proj").unwrap();
    let mut ranked: Vec<(usize, f64)> = per_layer
        .iter()
        .enumerate()
        .map(|(li, row)| {
            let r = row.as_arr().unwrap();
            (li, r[qi].as_f64().unwrap() + r[gi].as_f64().unwrap())
        })
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for budget in 0..=g.n_layers.min(4) {
        let skip: Vec<usize> =
            ranked.iter().take(budget).map(|(li, _)| *li).collect();
        w.row(vec![
            format!("{skip:?}"),
            format!("{:.1}%", g.coverage(&skip) * 100.0),
            format!("{:.2}x", g.ideal_linear_speedup(&skip, 2, 4)),
            format!("{:.2}x", g.ideal_linear_speedup(&skip, 8, 16)),
        ]);
    }
    w.print();
    println!("sensitivity_sweep OK");
    Ok(())
}
